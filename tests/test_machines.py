"""The declarative machine zoo: manifests, registry, transforms, Calibrator.

Acceptance (ISSUE 3): `repro.machines.get("gap8-fc")` loaded from its JSON
manifest produces bit-identical plans (selections and predicted totals) to
the legacy hard-coded constant across the Table-2 workload, sweeps run
end-to-end over >= 4 registered zoo machines, and the legacy
`core.hardware` imports keep working through deprecation shims.
"""
import dataclasses
import json
import os
import warnings

import numpy as np
import pytest

from repro import gemm, machines
from repro.core.mobilenet import TABLE2
from repro.core.simulator import search_batch, simulate
from repro.core.variants import MicroKernel, Variant
from repro.machines import MachineSpec, SpecValidationError

MB = 1.0e6
KiB = 1024
MiB = 1024 * 1024

# The paper's Table-1 numbers, restated literally: the manifest must stay
# bit-identical to the published calibration, independent of the zoo file.
LEGACY_GAP8 = MachineSpec(
    name="gap8-fc",
    capacities={"M": 8 * MiB, "L2": 512 * KiB, "L1": 16 * KiB, "R": 32 * 4},
    transfer_rates={
        ("M", "M"): 1.62e0 * MB,
        ("M", "L2"): 5.30e-1 * MB,
        ("L2", "M"): 6.54e-1 * MB,
        ("M", "L1"): 8.81e0 * MB,
        ("M", "R"): 4.87e-1 * MB,
        ("L1", "R"): 1.78e2 * MB,
        ("L2", "R"): 7.18e0 * MB,
    },
    arith_rate={"int8": 5.64e9},
    reference_chunk=4, elem_bytes=1,
    num_vector_registers=32, register_lanes=4,
)


@pytest.fixture(autouse=True)
def _clean_registry():
    before = set(machines.list_machines())
    yield
    for name in set(machines.list_machines()) - before:
        machines.unregister(name)
    machines.load_zoo()          # restore any zoo entry a test overwrote


# ---------------------------------------------------------------------------
# Manifest round trips + bit-identity vs the legacy constants
# ---------------------------------------------------------------------------


def test_zoo_manifests_roundtrip_to_json():
    names = machines.list_machines("zoo/*")
    assert len(names) >= 6
    for name in names:
        spec = machines.get(name)
        assert MachineSpec.from_json(spec.to_json()) == spec


def test_manifest_roundtrip_through_file(tmp_path):
    spec = machines.get("gap9-fc")
    path = spec.to_manifest(str(tmp_path / "gap9.json"))
    assert MachineSpec.from_manifest(path) == spec


def test_gap8_manifest_matches_paper_table1():
    zoo = machines.get("gap8-fc")
    assert dict(zoo.transfer_rates) == dict(LEGACY_GAP8.transfer_rates)
    assert dict(zoo.arith_rate) == dict(LEGACY_GAP8.arith_rate)
    assert {k: int(v) for k, v in zoo.capacities.items()} == \
        {k: int(v) for k, v in LEGACY_GAP8.capacities.items()}
    assert (zoo.reference_chunk, zoo.elem_bytes, zoo.num_vector_registers,
            zoo.register_lanes) == (4, 1, 32, 4)


def test_gap8_manifest_plans_bit_identical_to_legacy_table2():
    """Acceptance: the manifest-loaded machine reproduces the legacy
    constant's full Table-2 search bit-for-bit (selections AND totals)."""
    probs = [row.problem for row in TABLE2]
    got = search_batch(machines.get("gap8-fc"), probs)
    want = search_batch(LEGACY_GAP8, probs)
    for g, w in zip(got, want):
        assert g.variant is w.variant
        assert g.micro_kernel == w.micro_kernel
        assert g.blocking == w.blocking
        assert g.total == w.total           # bit-identical, not approx


def test_tpu_manifest_matches_legacy_roofline_constants():
    from repro.core.hardware import (V5E_HBM_BW, V5E_HBM_BYTES,
                                     V5E_PEAK_BF16, V5E_PEAK_INT8,
                                     V5E_VMEM_BW, V5E_VMEM_BYTES)
    zoo = machines.get("tpu-v5e")
    assert zoo.arith_rate == {"bf16": V5E_PEAK_BF16, "int8": V5E_PEAK_INT8,
                              "f32": V5E_PEAK_BF16 / 2}
    assert zoo.rate("M", "L1") == V5E_HBM_BW
    assert zoo.rate("L1", "R") == V5E_VMEM_BW
    assert zoo.capacity("M") == int(V5E_HBM_BYTES)
    assert zoo.capacity("L1") == int(V5E_VMEM_BYTES)
    # the L2 role collapses onto VMEM through the alias table
    assert zoo.level("L2") == "L1"
    assert zoo.capacity("L2") == int(V5E_VMEM_BYTES)


def test_tpu_manifest_tunes_identically_to_legacy(monkeypatch):
    from repro.core.autotune import clear_tune_cache, tune_batch
    from repro.core.tpu_model import GemmShape
    legacy = dataclasses.replace(
        machines.get("tpu-v5e"), name="tpu-v5e-legacy-check")
    shapes = [GemmShape(4096, 11008, 4096, "bf16"),
              GemmShape(100, 70, 130, "f32"), GemmShape(8, 512, 64, "int8")]
    clear_tune_cache()
    a = tune_batch(shapes, machine=machines.get("tpu-v5e"), cache=False)
    b = tune_batch(shapes, machine=legacy, cache=False)
    for x, y in zip(a, b):
        assert x.tile == y.tile and x.seconds == y.seconds


# ---------------------------------------------------------------------------
# Registry semantics
# ---------------------------------------------------------------------------


def test_get_unknown_machine_lists_known():
    with pytest.raises(KeyError, match="unknown machine 'nope'"):
        machines.get("nope")


def test_register_duplicate_requires_overwrite():
    spec = machines.get("gap8-fc").scaled(arith=1.5, name="dup-test")
    machines.register(spec)
    with pytest.raises(ValueError, match="already registered"):
        machines.register(spec)
    machines.register(spec, overwrite=True)
    assert machines.source_of("dup-test") == "runtime"


def test_register_validates():
    bad = dataclasses.replace(machines.get("gap8-fc"), name="bad-rate",
                              arith_rate={"int8": -1.0})
    with pytest.raises(SpecValidationError):
        machines.register(bad)


def test_alias_resolution_and_errors():
    machines.alias("edge-default", "gap8-fc")
    assert machines.get("edge-default") is machines.get("gap8-fc")
    with pytest.raises(KeyError):
        machines.alias("x", "not-a-machine")
    with pytest.raises(ValueError, match="shadow"):
        machines.alias("gap9-fc", "gap8-fc")
    spec = machines.get("gap8-fc").scaled(bw=2.0, name="edge-default")
    with pytest.raises(ValueError, match="taken by an alias"):
        machines.register(spec)
    machines.unregister("edge-default")


def test_glob_expansion():
    assert machines.list_machines("gap*") == ["gap8-fc", "gap9-fc"]
    assert set(machines.list_machines("zoo/*")) >= {
        "cortex-m7", "gap8-fc", "gap9-fc", "host-cpu", "tpu-v5e",
        "tpu-v5e-bw-half"}
    assert machines.expand("tpu-v5e*") == ["tpu-v5e", "tpu-v5e-bw-half"]
    assert machines.expand("gap8-fc") == ["gap8-fc"]
    with pytest.raises(KeyError, match="matched nothing"):
        machines.expand("zzz*")
    # runtime registrations are excluded from the zoo/ namespace
    machines.register(machines.get("gap8-fc").scaled(bw=3.0,
                                                     name="gap8-fc-fast"))
    assert "gap8-fc-fast" not in machines.list_machines("zoo/*")
    assert "gap8-fc-fast" in machines.list_machines("gap8*")


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------


def _base_json():
    return machines.get("gap8-fc").to_json()


def test_validation_rejects_undeclared_rate_level():
    d = _base_json()
    d["transfer_rates"]["M->L7"] = 1.0e6
    with pytest.raises(SpecValidationError, match="undeclared level"):
        MachineSpec.from_json(d)


def test_validation_rejects_missing_canonical_role():
    d = _base_json()
    # drop L1 entirely: the L1 role no longer resolves
    d["levels"] = ["M", "L2", "R"]
    d["capacities"].pop("L1")
    d["transfer_rates"] = {k: v for k, v in d["transfer_rates"].items()
                           if "L1" not in k}
    with pytest.raises(SpecValidationError, match="canonical role"):
        MachineSpec.from_json(d)


def test_validation_rejects_bad_dtype_table():
    d = _base_json()
    d["arith_rate"] = {}
    with pytest.raises(SpecValidationError, match="empty"):
        MachineSpec.from_json(d)
    d["arith_rate"] = {"INT8!": 1.0}
    with pytest.raises(SpecValidationError, match="dtype tag"):
        MachineSpec.from_json(d)


def test_validation_rejects_alias_shadowing_level():
    d = _base_json()
    d["level_aliases"] = {"L1": "L2"}
    with pytest.raises(SpecValidationError, match="shadows"):
        MachineSpec.from_json(d)


def test_validation_rejects_unknown_schema():
    d = _base_json()
    d["schema"] = "somebody-else/v9"
    with pytest.raises(SpecValidationError, match="schema"):
        MachineSpec.from_json(d)


# ---------------------------------------------------------------------------
# Derived-machine transforms
# ---------------------------------------------------------------------------


def test_scaled_transform_scales_simulated_components():
    base = machines.get("gap8-fc")
    fast = base.scaled(arith=2.0, bw=4.0, name="gap8-fc-fast2")
    assert fast.provenance == {
        "base": "gap8-fc",
        "transform": {"scaled": {"arith": 2.0, "bw": 4.0}}}
    prob = TABLE2[9].problem
    mk = MicroKernel(4, 8)
    a = simulate(base, Variant.B3A2C0, mk, prob)
    b = simulate(fast, Variant.B3A2C0, mk, prob)
    assert b.arith == a.arith / 2.0
    assert b.transfer == pytest.approx(a.transfer / 4.0, rel=1e-12)


def test_with_capacities_transform():
    base = machines.get("gap8-fc")
    big = base.with_capacities(L1=64 * KiB, name="gap8-fc-bigl1")
    assert big.capacity("L1") == 64 * KiB
    assert big.capacity("L2") == base.capacity("L2")
    with pytest.raises(KeyError, match="no such level"):
        base.with_capacities(VMEM=1)
    # a bigger L1 can only improve (or tie) the best simulated total
    prob = TABLE2[9].problem
    t_base = search_batch(base, [prob])[0].total
    t_big = search_batch(big, [prob])[0].total
    assert t_big <= t_base


def test_with_dtype_rates_transform():
    base = machines.get("gap8-fc")
    multi = base.with_dtype_rates(int4=2 * base.arith_rate["int8"],
                                  name="gap8-fc-int4")
    assert multi.arith_rate["int4"] == 2 * base.arith_rate["int8"]
    assert multi.arith_rate["int8"] == base.arith_rate["int8"]
    multi.validate()


def test_derived_names_auto_suffix_and_are_registrable():
    d = machines.get("tpu-v5e").scaled(bw=0.5)
    assert d.name == "tpu-v5e+arith1x+bw0.5x"
    machines.register(d)
    assert machines.get(d.name).rate("M", "L1") == \
        machines.get("tpu-v5e").rate("M", "L1") * 0.5


def test_bw_half_zoo_ablation_matches_transform():
    half = machines.get("tpu-v5e-bw-half")
    derived = machines.get("tpu-v5e").scaled(bw=0.5)
    assert dict(half.transfer_rates) == dict(derived.transfer_rates)
    assert dict(half.arith_rate) == dict(derived.arith_rate)


# ---------------------------------------------------------------------------
# Sweeps over the zoo (acceptance: >= 4 machines end-to-end)
# ---------------------------------------------------------------------------


def test_sweep_accepts_names_specs_and_globs():
    probs = [row.problem for row in TABLE2[:2]]
    spec = machines.get("gap8-fc").scaled(arith=2.0, name="gap8-fc-sweepspec")
    res = gemm.sweep(probs, backends=["analytic-gap8"],
                     machines=["gap*", "cortex-m7", "host-cpu", spec],
                     cache=False)
    names = {r.machine for r in res.rows}
    assert names == {"gap8-fc", "gap9-fc", "cortex-m7", "host-cpu",
                     "gap8-fc-sweepspec"}
    assert len(res.rows) == len(probs) * len(names)
    assert all(r.seconds > 0 for r in res.rows)
    # the 2x-arith derived spec must beat its base on the same problem
    for p in probs:
        fast = [r for r in res.rows if r.machine == "gap8-fc-sweepspec"
                and r.problem.m == p.m]
        base = [r for r in res.rows if r.machine == "gap8-fc"
                and r.problem.m == p.m]
        assert fast[0].seconds < base[0].seconds


def test_sweep_zoo_glob_tpu_backend():
    res = gemm.sweep([(512, 2048, 1024)], backends=["analytic-tpu"],
                     machines=["tpu-v5e*"], cache=False)
    by = {r.machine: r for r in res.rows}
    assert set(by) == {"tpu-v5e", "tpu-v5e-bw-half"}
    assert by["tpu-v5e-bw-half"].seconds > by["tpu-v5e"].seconds


def test_two_level_machine_runs_gap8_model():
    """cortex-m7 has no L2: the role aliases onto L1 and the whole variant
    family still simulates (level-name indirection)."""
    m7 = machines.get("cortex-m7")
    assert m7.level("L2") == "L1"
    cb = search_batch(m7, [TABLE2[3].problem])[0]
    assert cb.total > 0
    assert m7.rate("L2", "R") == m7.rate("L1", "R")


# ---------------------------------------------------------------------------
# Calibrator: vectorized fit == scalar oracle, rate recovery, provenance
# ---------------------------------------------------------------------------

_FIT_MKS = [MicroKernel(4, 24), MicroKernel(8, 12), MicroKernel(12, 8),
            MicroKernel(16, 4)]


def _fit_samples(n=24, seed=0):
    rng = np.random.default_rng(seed)
    probs = [(int(m), int(nn), int(k)) for m, nn, k in
             zip(rng.integers(16, 4096, n), rng.integers(16, 4096, n),
                 rng.integers(16, 8192, n))]
    mks = [_FIT_MKS[i % len(_FIT_MKS)] for i in range(n)]
    return probs, mks


def test_calibrator_design_matrix_batch_equals_scalar():
    probs, mks = _fit_samples()
    cal = machines.Calibrator("gap8-fc")
    A_batch, cols_batch = cal.design_matrix(probs, mks)
    A_scalar, cols_scalar = cal.design_matrix_scalar(probs, mks)
    assert cols_batch == cols_scalar
    assert np.array_equal(A_batch, A_scalar)      # bitwise, not approx
    cal2 = machines.Calibrator("tpu-v5e")
    B_batch, c1 = cal2.design_matrix(probs)
    B_scalar, c2 = cal2.design_matrix_scalar(probs)
    assert c1 == c2 and np.array_equal(B_batch, B_scalar)


def test_calibrator_fit_recovers_known_rates():
    gap8 = machines.get("gap8-fc")
    cal = machines.Calibrator("gap8-fc")
    probs, mks = _fit_samples()
    times = [simulate(gap8, Variant.B3A2C0, mk,
                      cal._coerce_problems([p])[0].as_problem()).total
             for p, mk in zip(probs, mks)]
    spec, report = cal.fit(probs, times, micro_kernels=mks,
                           date="2026-07-27", name="gap8-refit")
    for key, rate in spec.transfer_rates.items():
        assert rate == pytest.approx(gap8.transfer_rates[key], rel=1e-6)
    assert spec.arith_rate["int8"] == pytest.approx(
        gap8.arith_rate["int8"], rel=1e-6)
    assert report.residual_rms_s < 1e-6
    assert report.samples == len(probs)
    fit = spec.provenance["fit"]
    assert fit["date"] == "2026-07-27"
    assert fit["samples"] == len(probs)
    assert fit["cost_model"]["variant"] == "B3A2C0"
    assert spec.provenance["base"] == "gap8-fc"


def test_calibrator_single_microkernel_fit_is_underdetermined():
    """With one micro-kernel every streaming column is proportional to
    m*n*k — the recovered rates are NOT trustworthy.  The design matrix is
    rank-deficient and fit() must refuse to emit (let alone register) a
    spec from it."""
    cal = machines.Calibrator("gap8-fc", micro_kernel=MicroKernel(4, 8))
    probs, _ = _fit_samples()
    A, cols = cal.design_matrix(probs)
    assert np.linalg.matrix_rank(A) < len(cols)
    with pytest.raises(ValueError, match="rank-deficient"):
        cal.fit(probs, [1.0] * len(probs), date=None)


def test_calibrator_fit_registers_and_persists(tmp_path):
    gap8 = machines.get("gap8-fc")
    cal = machines.Calibrator("gap8-fc")
    probs, mks = _fit_samples(n=16, seed=3)
    times = [simulate(gap8, Variant.B3A2C0, mk,
                      cal._coerce_problems([p])[0].as_problem()).total
             for p, mk in zip(probs, mks)]
    spec, _ = cal.fit(probs, times, micro_kernels=mks, date=None,
                      name="gap8-fit-persisted", register=True,
                      manifest_dir=str(tmp_path))
    assert machines.get("gap8-fit-persisted") is spec
    assert machines.source_of("gap8-fit-persisted") == "calibrated"
    path = tmp_path / "gap8-fit-persisted.json"
    assert MachineSpec.from_manifest(str(path)) == spec
    # the calibrated machine immediately feeds the planner
    plan = gemm.plan(TABLE2[0].problem, backend="analytic-gap8",
                     machine="gap8-fit-persisted", cache=False)
    assert plan.machine == "gap8-fit-persisted"


def test_calibrator_rejects_underdetermined_sample_count():
    cal = machines.Calibrator("gap8-fc")
    with pytest.raises(ValueError, match="under-determined"):
        cal.fit([(64, 64, 64)], [1.0], date=None)


def test_calibrate_host_wraps_pipeline(monkeypatch):
    """calibrate_host delegates to Calibrator.measure_host and the result
    can feed the registry; micro-experiments are monkeypatched to stay
    fast and deterministic."""
    from repro.core import calibrate as cal_mod
    monkeypatch.setattr(cal_mod, "measure_packing_rate", lambda c: 2.0e9)
    monkeypatch.setattr(cal_mod, "measure_copy_rate", lambda: 8.0e9)
    monkeypatch.setattr(cal_mod, "measure_arith_rate", lambda: 5.0e10)
    spec = cal_mod.calibrate_host("host-test", date="2026-07-27",
                                  register=True)
    assert spec.rate("M", "M") == 2.0e9
    assert spec.rate("M", "L1") == 8.0e9
    assert spec.arith_rate["f32"] == 5.0e10
    assert spec.provenance["calibration"]["date"] == "2026-07-27"
    assert machines.get("host-test") is spec
    assert search_batch(spec, [TABLE2[0].problem])[0].total > 0


# ---------------------------------------------------------------------------
# Legacy shims + CLI
# ---------------------------------------------------------------------------


def test_legacy_hardware_shims_warn_but_work():
    from repro.core import hardware
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        gap8 = hardware.GAP8_FC
        tpu = hardware.get_machine("tpu-v5e")
        zoo = hardware.MACHINES
    assert len(w) == 3
    assert all(issubclass(x.category, DeprecationWarning) for x in w)
    assert gap8 is machines.get("gap8-fc")
    assert tpu is machines.get("tpu-v5e")
    assert "gap8-fc" in zoo and "tpu-v5e" in zoo
    with pytest.raises(KeyError):
        hardware.get_machine("nope")
    # repro.core re-exports stay silent (they resolve via the registry);
    # equality not identity — the registry may have been reloaded since
    # repro.core bound the name at import time.
    from repro.core import GAP8_FC
    assert GAP8_FC == machines.get("gap8-fc")


def test_cli_validate_and_show(capsys, tmp_path):
    from repro.machines.__main__ import main
    assert main(["validate"]) == 0
    out = capsys.readouterr().out
    assert "manifests valid" in out and "FAIL" not in out
    assert main(["show", "gap8-fc"]) == 0
    shown = json.loads(capsys.readouterr().out)
    assert shown["name"] == "gap8-fc"
    # a broken manifest dir fails
    bad = dict(shown)
    bad["arith_rate"] = {}
    (tmp_path / "bad.json").write_text(json.dumps(bad))
    assert main(["validate", "--dir", str(tmp_path)]) == 1


def test_plan_cache_distinguishes_machines():
    gemm.clear_plan_cache()
    p1 = gemm.plan((64, 96, 128), backend="analytic-gap8",
                   machine="gap8-fc")
    p2 = gemm.plan((64, 96, 128), backend="analytic-gap8",
                   machine="gap9-fc")
    assert p1 is not p2
    assert p1.machine == "gap8-fc" and p2.machine == "gap9-fc"
    gemm.clear_plan_cache()


def test_plan_cache_keys_on_machine_content_not_name():
    """Two same-named specs with different rate tables must not share
    cached plans (derived transforms / re-registered calibrations)."""
    gemm.clear_plan_cache()
    base = machines.get("gap8-fc")
    a = base.with_capacities(L1=8 * KiB)
    b = base.with_capacities(L1=64 * KiB)
    assert a.name == b.name and a.fingerprint() != b.fingerprint()
    prob = TABLE2[9].problem
    pa = gemm.plan(prob, backend="analytic-gap8", machine=a)
    pb = gemm.plan(prob, backend="analytic-gap8", machine=b)
    assert pa is not pb
    assert pa.predicted_seconds != pb.predicted_seconds
    assert pb.predicted_seconds == search_batch(b, [prob])[0].total
    gemm.clear_plan_cache()


def test_tune_cache_keys_on_machine_content_not_name():
    from repro.core.autotune import tune_batch
    from repro.core.tpu_model import GemmShape
    base = machines.get("tpu-v5e")
    half = dataclasses.replace(base.scaled(bw=0.5), name=base.name)
    shape = GemmShape(512, 2048, 1024, "bf16")
    a = tune_batch([shape], machine=base)[0]
    b = tune_batch([shape], machine=half)[0]
    assert b.seconds > a.seconds        # not the memoised full-bw decision


def test_load_zoo_custom_dir_does_not_shadow_builtin_zoo(tmp_path):
    from repro.machines import registry as reg
    spec = machines.get("gap8-fc").scaled(bw=2.0, name="custom-zoo-machine")
    spec.to_manifest(str(tmp_path / "custom.json"))
    # emulate a fresh process whose FIRST registry touch is the custom dir:
    # the built-in zoo must still load underneath it.
    reg._REGISTRY.clear()
    reg._SOURCES.clear()
    reg._ALIASES.clear()
    reg._zoo_loaded = False
    names = machines.load_zoo(str(tmp_path))
    assert names == ["custom-zoo-machine"]
    assert machines.get("tpu-v5e") is not None
    assert "gap8-fc" in machines.list_machines("zoo/*")
    machines.unregister("custom-zoo-machine")


# ---------------------------------------------------------------------------
# Sweep-driven serving autoconfig
# ---------------------------------------------------------------------------


def test_serving_autoconfigure_picks_best_grid_point():
    import jax
    from repro.configs import get_config
    from repro.models.common import HOST_MESH, split_params
    from repro.models.model import LM
    from repro.serving.engine import Request, ServingEngine

    cfg = get_config("qwen2-1.5b", smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    eng = ServingEngine.autoconfigure(lm, values, dtypes=("bf16", "int8"),
                                      batches=(1, 4), max_len=64)
    ac = eng.autoconfig
    assert eng.max_batch == ac["max_batch"] and ac["max_batch"] in (1, 4)
    # the operating point is chosen among the model's *native* dtype rows
    # (the engine really decodes in bf16); what-if dtypes only inform the
    # recorded grid.
    assert ac["native_dtype"] == "bf16" and ac["dtype"] == "bf16"
    native_best = max((g for g in ac["grid"] if g["dtype"] == "bf16"),
                      key=lambda g: g["predicted_tokens_per_second"])
    assert ac["predicted_tokens_per_second"] == \
        native_best["predicted_tokens_per_second"]
    assert len(ac["grid"]) == 4          # 2 batches x 2 dtypes
    # frozen plans match the chosen operating point
    assert all(p.problem.dtype == ac["dtype"] for p in eng.gemm_plans)
    assert all(p.problem.m == ac["max_batch"] for p in eng.gemm_plans[:2])
    assert "autoconfig" in eng.perf_report()
    # the autoconfigured engine still serves
    eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].generated) == 3
