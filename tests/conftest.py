"""Test-suite bootstrap.

* Puts ``src/`` on ``sys.path`` so ``pytest`` works without exporting
  ``PYTHONPATH`` (the documented tier-1 command still works unchanged).
* If ``hypothesis`` is not installed (the offline container cannot pip
  install), registers the deterministic fallback from
  ``_hypothesis_fallback.py`` so all test modules collect and the property
  tests still run.  CI installs the real hypothesis via
  ``requirements-dev.txt``.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    from _hypothesis_fallback import install

    install()
