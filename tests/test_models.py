"""Model substrate tests: per-arch smoke, decode==prefill, SSD equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCH_IDS, get_config, input_specs, shape_cells
from repro.configs.base import SHAPES
from repro.models import layers
from repro.models.common import HOST_MESH, MeshInfo, Param, is_param, split_params
from repro.models.model import LM, factor_pattern
from repro.models.ssm import ssd_chunked, ssd_decode_step
from repro.models.moe import apply_moe


def _batch_for(cfg, b, s, key):
    if cfg.frontend == "audio_stub":
        return {"frames": jax.random.normal(key, (b, s, cfg.d_model)
                                            ).astype(jnp.bfloat16),
                "labels": jnp.zeros((b, s), jnp.int32)}
    if cfg.frontend == "vision_stub":
        st_ = s - cfg.num_prefix_tokens
        return {"patches": jax.random.normal(
                    key, (b, cfg.num_prefix_tokens, cfg.d_model)
                ).astype(jnp.bfloat16),
                "tokens": jax.random.randint(key, (b, st_), 0, cfg.vocab_size),
                "labels": jnp.zeros((b, st_), jnp.int32)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "labels": jnp.zeros((b, s), jnp.int32)}


# ---------------------------------------------------------------------------
# Per-arch smoke: one forward/train step on CPU, shapes + no NaNs (assignment)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, specs = split_params(lm.init(jax.random.key(0)))
    # spec tree mirrors value tree exactly
    assert jax.tree.structure(values) == jax.tree.structure(specs)
    batch = _batch_for(cfg, 2, 32, jax.random.key(1))

    def loss(v):
        l, m = lm.loss_fn(v, batch)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(values)
    assert jnp.isfinite(val), arch
    gleaves = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in gleaves), arch
    # at least one grad must be nonzero
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in gleaves), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_exact_hparams(arch):
    """The full configs carry the assignment's exact hyper-parameters."""
    expect = {
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 0, 163840),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 0, 49155),
    }[arch]
    c = get_config(arch)
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == expect
    if arch == "kimi-k2-1t-a32b":
        assert (c.n_experts, c.experts_per_token, c.moe_d_ff) == (384, 8, 2048)
        # ~1T total, ~32B active
        assert 0.8e12 < c.param_count() < 1.3e12
        assert c.active_param_count() < 0.06 * c.param_count()
    if arch == "granite-moe-3b-a800m":
        assert (c.n_experts, c.experts_per_token, c.moe_d_ff) == (40, 8, 512)
    if arch == "zamba2-1.2b":
        assert c.ssm_state == 64 and c.shared_block


def test_param_counts_in_expected_range():
    approx = {"qwen2-7b": 7.6e9, "qwen2-1.5b": 1.5e9, "qwen2.5-32b": 32.5e9,
              "stablelm-12b": 12.1e9, "paligemma-3b": 2.9e9,
              "musicgen-medium": 1.5e9, "xlstm-125m": 0.125e9}
    for arch, n in approx.items():
        got = get_config(arch).param_count()
        assert 0.55 * n < got < 1.6 * n, (arch, got, n)


# ---------------------------------------------------------------------------
# decode == prefill (cache-path correctness)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-7b", "stablelm-12b", "musicgen-medium",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_prefill_attention_archs(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:  # avoid capacity-drop divergence: generous capacity
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(1)))
    b, s = 2, 12
    if cfg.frontend == "audio_stub":
        frames = jax.random.normal(jax.random.key(2), (b, s, cfg.d_model)
                                   ).astype(jnp.bfloat16)
        lg_full, _ = lm.prefill(values, {"frames": frames})
        caches, _ = split_params(lm.init_cache(b, max_len=s + 4))
        for t in range(s):
            lg, caches = lm.decode_step(values, caches, frames[:, t:t + 1],
                                        jnp.int32(t))
    else:
        toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)
        lg_full, _ = lm.prefill(values, {"tokens": toks})
        caches, _ = split_params(lm.init_cache(b, max_len=s + 4))
        for t in range(s):
            lg, caches = lm.decode_step(values, caches, toks[:, t:t + 1],
                                        jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_full, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["zamba2-1.2b", "xlstm-125m"])
def test_decode_matches_prefill_recurrent_archs(arch):
    """Recurrent archs: chunked-parallel vs step recurrence agree within
    bf16 accumulation tolerance."""
    cfg = get_config(arch, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(1)))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.key(2), (b, s), 0, cfg.vocab_size)
    lg_full, _ = lm.prefill(values, {"tokens": toks})
    caches, _ = split_params(lm.init_cache(b, max_len=s + 4))
    for t in range(s):
        lg, caches = lm.decode_step(values, caches, toks[:, t:t + 1],
                                    jnp.int32(t))
    scale = float(jnp.max(jnp.abs(lg_full.astype(jnp.float32)))) + 1e-6
    err = float(jnp.max(jnp.abs(lg.astype(jnp.float32)
                                - lg_full.astype(jnp.float32))))
    assert err / scale < 0.06, (arch, err, scale)


# ---------------------------------------------------------------------------
# SSD core: chunked == recurrent (exact, f32)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), s=st.integers(3, 40),
       chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_equals_recurrence(seed, s, chunk):
    rng = np.random.default_rng(seed)
    B, H, P, N = 2, 3, 5, 4
    xh = jnp.array(rng.normal(size=(B, s, H, P)), jnp.float32)
    a = -jnp.abs(jnp.array(rng.normal(size=(B, s, H)), jnp.float32)) * 0.3
    dt = jnp.abs(jnp.array(rng.normal(size=(B, s, H)), jnp.float32))
    Bm = jnp.array(rng.normal(size=(B, s, H, N)), jnp.float32)
    Cm = jnp.array(rng.normal(size=(B, s, H, N)), jnp.float32)
    y_chunk, h_chunk = ssd_chunked(xh, a, dt, Bm, Cm, chunk=chunk)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(s):
        y_t, h = ssd_decode_step(h, xh[:, t], a[:, t], dt[:, t], Bm[:, t],
                                 Cm[:, t])
        ys.append(y_t)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=1e-4, atol=1e-4)


def test_ssd_state_passing_across_calls():
    """Splitting a sequence across two chunked calls with carried state must
    equal one call — the invariant behind multi-segment prefill."""
    rng = np.random.default_rng(3)
    B, S, H, P, N = 1, 24, 2, 4, 4
    xh = jnp.array(rng.normal(size=(B, S, H, P)), jnp.float32)
    a = -jnp.abs(jnp.array(rng.normal(size=(B, S, H)), jnp.float32)) * 0.2
    dt = jnp.abs(jnp.array(rng.normal(size=(B, S, H)), jnp.float32))
    Bm = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    Cm = jnp.array(rng.normal(size=(B, S, H, N)), jnp.float32)
    y_all, h_all = ssd_chunked(xh, a, dt, Bm, Cm, chunk=8)
    half = S // 2
    y1, h1 = ssd_chunked(xh[:, :half], a[:, :half], dt[:, :half],
                         Bm[:, :half], Cm[:, :half], chunk=8)
    y2, h2 = ssd_chunked(xh[:, half:], a[:, half:], dt[:, half:],
                         Bm[:, half:], Cm[:, half:], chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_all), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Attention: blockwise == naive softmax; prefix mask; head padding exactness
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, causal=True, prefix_len=0):
    b, s, h, d = q.shape
    s_kv = k.shape[1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (d ** -0.5)
    mask = jnp.ones((s, s_kv), bool)
    if causal:
        mask = jnp.tril(jnp.ones((s, s_kv), bool))
        if prefix_len:
            mask = mask | (jnp.arange(s_kv)[None, :] < prefix_len)
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), s=st.integers(2, 33),
       chunk=st.sampled_from([4, 8, 64]), prefix=st.integers(0, 6))
def test_blockwise_attention_matches_naive(seed, s, chunk, prefix):
    from repro.models.attention import blockwise_attention
    rng = np.random.default_rng(seed)
    b, h, d = 2, 3, 8
    q = jnp.array(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.array(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.array(rng.normal(size=(b, s, h, d)), jnp.float32)
    got = blockwise_attention(q, k, v, chunk=chunk, causal=True,
                              prefix_len=min(prefix, s))
    want = _naive_attention(q, k, v, causal=True, prefix_len=min(prefix, s))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch,tp", [("qwen2-7b", 4),       # GQA group pad
                                     ("musicgen-medium", 4),  # MHA both pad
                                     ("paligemma-3b", 8)])    # MQA extreme
def test_head_padding_is_exact(arch, tp):
    """Padding heads to the TP multiple (grouped per KV head) must not
    change attention outputs: same init key -> identical logical weights,
    zero-filled pad positions."""
    from repro.models.attention import apply_attention, head_layout, init_attention
    cfg = get_config(arch, smoke=True)
    x = jax.random.normal(jax.random.key(0), (2, 16, cfg.d_model),
                          jnp.float32)
    lm_plain = MeshInfo(data=1, model=1)
    lm_pad = MeshInfo(data=1, model=tp)
    hq_p, hkv_p = head_layout(cfg, lm_pad)
    assert hq_p % tp == 0
    assert hq_p >= cfg.n_heads and hkv_p >= 1
    p1, _ = split_params(init_attention(jax.random.key(5), cfg, lm_plain,
                                        jnp.float32))
    p2, _ = split_params(init_attention(jax.random.key(5), cfg, lm_pad,
                                        jnp.float32))
    y1 = apply_attention(p1, x, cfg, lm_plain)
    y2 = apply_attention(p2, x, cfg, lm_pad)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------


def test_moe_gates_normalised_and_capacity_exact():
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b", smoke=True),
                              capacity_factor=64.0)
    from repro.models.moe import init_moe
    p, _ = split_params(init_moe(jax.random.key(0), cfg, HOST_MESH,
                                 jnp.float32))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model), jnp.float32)
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """With capacity factor 1.0+, dropped-token output is the residual only —
    outputs stay finite and bounded."""
    cfg = get_config("granite-moe-3b-a800m", smoke=True)
    from repro.models.moe import init_moe
    p, _ = split_params(init_moe(jax.random.key(0), cfg, HOST_MESH,
                                 jnp.float32))
    x = jax.random.normal(jax.random.key(1), (4, 32, cfg.d_model), jnp.float32)
    y, aux = apply_moe(p, x, cfg)
    assert jnp.all(jnp.isfinite(y))


# ---------------------------------------------------------------------------
# Pattern factoring
# ---------------------------------------------------------------------------


def test_factor_pattern():
    assert factor_pattern(("attn",) * 8) == (("attn",), 8, ())
    assert factor_pattern(("mlstm", "slstm") * 6) == (("mlstm", "slstm"), 6, ())
    p = ("mamba2",) * 5 + ("shared_attn",)
    assert factor_pattern(p * 6 + ("mamba2", "mamba2")) == (p, 6, ("mamba2", "mamba2"))
    assert factor_pattern(("a", "b", "a")) == (("a", "b"), 1, ("a",))


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 3, 8))
    labels = jnp.array([[1, 2, 3]])
    l1 = layers.cross_entropy(logits, labels, vocab_size=8)
    l2 = layers.cross_entropy(jnp.pad(logits, ((0, 0), (0, 0), (0, 4)),
                                      constant_values=5.0),
                              labels, vocab_size=8)
    assert jnp.allclose(l1, l2, atol=1e-5)


def test_int8_kv_cache_decode_close_to_bf16():
    """EXPERIMENTS.md §Perf D2: int8 KV entries with per-(pos, head) scales
    stay within ~2% of the bf16-cache decode."""
    import dataclasses as _dc
    cfg = get_config("qwen2-7b", smoke=True)
    cfg8 = _dc.replace(cfg, kv_cache_dtype="int8")
    lm, lm8 = LM(cfg, HOST_MESH), LM(cfg8, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(1)))
    toks = jax.random.randint(jax.random.key(2), (2, 10), 0, cfg.vocab_size)
    c1, _ = split_params(lm.init_cache(2, 16))
    c2, _ = split_params(lm8.init_cache(2, 16))
    assert c2["stack"]["b0_attn"]["k"].dtype == jnp.int8
    for t in range(10):
        lg1, c1 = lm.decode_step(values, c1, toks[:, t:t + 1], jnp.int32(t))
        lg2, c2 = lm8.decode_step(values, c2, toks[:, t:t + 1], jnp.int32(t))
    scale = float(jnp.max(jnp.abs(lg1.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs(lg1.astype(jnp.float32)
                                - lg2.astype(jnp.float32)))) / scale
    assert err < 0.05, err
