"""Tests for the paper's GEMM performance simulator (core/)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hardware import GAP8_FC, TPU_V5E
from repro.core.mobilenet import LAYER10, TABLE2
from repro.core.simulator import best_microkernel, simulate
from repro.core.variants import (
    Blocking,
    MicroKernel,
    Problem,
    Variant,
    derive_blocking,
    feasible_microkernels,
    loop_trip_counts,
    registers_needed,
    traffic_terms,
)


# ---------------------------------------------------------------------------
# Micro-kernel feasibility (paper §3.1: 32 regs x 4 INT8 lanes)
# ---------------------------------------------------------------------------

def test_feasible_set_matches_paper():
    mks = {(m.rows, m.cols) for m in feasible_microkernels(GAP8_FC, Variant.B3A2C0)}
    # every micro-kernel appearing in Table 2 must be feasible
    for row in TABLE2:
        for v in Variant:
            mk = row.best[v.value]
            assert (mk.rows, mk.cols) in mks, (row.layer, v, mk)
    # the paper's headline kernels
    assert (4, 24) in mks and (8, 12) in mks and (12, 8) in mks and (24, 4) in mks
    # too big for 32 registers
    assert (16, 8) not in mks
    assert (4, 28) not in mks


def test_registers_needed():
    # 4x24: 24 regs for C_r + 1 for A column + 6 for B row = 31
    assert registers_needed(Variant.B3A2C0, MicroKernel(4, 24), 4) == pytest.approx(31.0)
    assert registers_needed(Variant.B3A2C0, MicroKernel(8, 12), 4) == pytest.approx(29.0)


# ---------------------------------------------------------------------------
# Blocking derivation (paper §3.2 occupancy rule)
# ---------------------------------------------------------------------------

def test_blocking_b3a2c0_layer10():
    blk = derive_blocking(Variant.B3A2C0, MicroKernel(4, 24), GAP8_FC, LAYER10)
    # B_r = k_c x n_r fills the 16 KiB L1
    assert blk.k_c == 16 * 1024 // 24
    assert blk.k_c * 24 <= GAP8_FC.capacity("L1")
    # A_c = m_c x k_c fits L2 (m capped by problem)
    assert blk.m_c <= LAYER10.m
    assert blk.n_c == LAYER10.n


def test_blocking_c3b2a0_layer10():
    blk = derive_blocking(Variant.C3B2A0, MicroKernel(12, 8), GAP8_FC, LAYER10)
    assert blk.n_c == min(16 * 1024 // 12, LAYER10.n)
    assert blk.k_c * blk.n_c <= GAP8_FC.capacity("L2")
    assert blk.m_c == LAYER10.m


def test_blocking_respects_problem_dims():
    p = Problem(8, 8, 8)
    for v in Variant:
        blk = derive_blocking(v, MicroKernel(4, 4), GAP8_FC, p)
        assert blk.m_c <= p.m and blk.n_c <= p.n and blk.k_c <= p.k


# ---------------------------------------------------------------------------
# Traffic closed forms vs. a literal loop-nest walk
# ---------------------------------------------------------------------------

def _walk_b3a2c0(mk, blk, p):
    """Literal walk of Fig. 1's loop nest counting bytes per term."""
    s = p.elem_bytes
    t = {"pack_B": 0, "pack_A": 0, "copy_Br": 0, "stream_C": 0,
         "stream_A": 0, "stream_B": 0}
    for jc in range(0, p.n, blk.n_c):
        nc = min(blk.n_c, p.n - jc)
        for pc in range(0, p.k, blk.k_c):
            kc = min(blk.k_c, p.k - pc)
            t["pack_B"] += s * kc * nc
            for ic in range(0, p.m, blk.m_c):
                mc = min(blk.m_c, p.m - ic)
                t["pack_A"] += s * mc * kc
                for jr in range(0, nc, mk.cols):
                    nr = min(mk.cols, nc - jr)
                    t["copy_Br"] += s * kc * nr
                    for ir in range(0, mc, mk.rows):
                        mr = min(mk.rows, mc - ir)
                        t["stream_C"] += 2 * s * mr * nr
                        t["stream_A"] += s * mr * kc
                        t["stream_B"] += s * kc * nr
    return t


@pytest.mark.parametrize("dims", [(256, 784, 2304), (64, 96, 48), (48, 48, 96),
                                  (100, 60, 250)])
def test_b3a2c0_closed_forms_sandwich_walk(dims):
    """The literal loop-nest walk (exact partial tiles) must lie between the
    'analytic' closed form (exact ratios: lower bound) and the 'padded'
    closed form (full-tile edge blocks: upper bound)."""
    m, n, k = dims
    p = Problem(m, n, k)
    mk = MicroKernel(4, 8)
    blk = derive_blocking(Variant.B3A2C0, mk, GAP8_FC, p)
    walked = _walk_b3a2c0(mk, blk, p)
    lo = {t.name: t.bytes for t in
          traffic_terms(Variant.B3A2C0, mk, blk, p, policy="analytic")}
    hi = {t.name: t.bytes for t in
          traffic_terms(Variant.B3A2C0, mk, blk, p, policy="padded")}
    for name, b in walked.items():
        assert lo[name] <= b * (1 + 1e-9), name
        # multiple partial outer blocks can each round up once, so allow a
        # small slack above the single-ceil padded form.
        assert b <= hi[name] * 1.25 + 1e-9, name


def test_b3a2c0_closed_form_exact_when_divisible():
    p = Problem(48, 96, 64)
    mk = MicroKernel(4, 8)
    blk = Blocking(m_c=24, n_c=48, k_c=32)
    walked = _walk_b3a2c0(mk, blk, p)
    terms = {t.name: t.bytes for t in
             traffic_terms(Variant.B3A2C0, mk, blk, p, policy="analytic")}
    for name, b in walked.items():
        assert terms[name] == pytest.approx(b, rel=1e-9), name


def _walk_c3b2a0(mk, blk, p):
    """Literal walk of Fig. 3 (top): C3B2A0 loop nest."""
    s = p.elem_bytes
    t = {"pack_C": 0, "unpack_C": 0, "pack_B": 0, "copy_Cr": 0,
         "stream_A": 0, "stream_B": 0, "stream_C": 0}
    for jc in range(0, p.n, blk.n_c):
        nc = min(blk.n_c, p.n - jc)
        for ic in range(0, p.m, blk.m_c):
            mc = min(blk.m_c, p.m - ic)
            t["pack_C"] += s * mc * nc
            t["unpack_C"] += s * mc * nc
            for pc in range(0, p.k, blk.k_c):
                kc = min(blk.k_c, p.k - pc)
                t["pack_B"] += s * kc * nc
                for ir in range(0, mc, mk.rows):
                    mr = min(mk.rows, mc - ir)
                    t["copy_Cr"] += 2 * s * mr * nc
                    for pr in range(0, kc, mk.cols):
                        kr = min(mk.cols, kc - pr)
                        t["stream_A"] += s * mr * kr
                        for jr in range(nc):
                            t["stream_B"] += s * kr
                            t["stream_C"] += 2 * s * mr
    return t


def _walk_b3c2a0(mk, blk, p):
    """Literal walk of Fig. 3 (bottom): B3C2A0 loop nest."""
    s = p.elem_bytes
    t = {"pack_B": 0, "pack_C": 0, "unpack_C": 0, "copy_Br": 0,
         "stream_A": 0, "stream_B": 0, "stream_C": 0}
    for jc in range(0, p.n, blk.n_c):
        nc = min(blk.n_c, p.n - jc)
        for pc in range(0, p.k, blk.k_c):
            kc = min(blk.k_c, p.k - pc)
            t["pack_B"] += s * kc * nc
            for ic in range(0, p.m, blk.m_c):
                mc = min(blk.m_c, p.m - ic)
                t["pack_C"] += s * mc * nc
                t["unpack_C"] += s * mc * nc
                for pr in range(0, kc, mk.cols):
                    kr = min(mk.cols, kc - pr)
                    t["copy_Br"] += s * kr * nc
                    for ir in range(0, mc, mk.rows):
                        mr = min(mk.rows, mc - ir)
                        t["stream_A"] += s * mr * kr
                        for jr in range(nc):
                            t["stream_C"] += 2 * s * mr
                            t["stream_B"] += s * kr
    return t


def test_c3b2a0_closed_form_exact_when_divisible():
    p = Problem(48, 96, 64)
    mk = MicroKernel(4, 8)        # m_r x k_r
    blk = Blocking(m_c=24, n_c=48, k_c=32)
    walked = _walk_c3b2a0(mk, blk, p)
    terms = {t.name: t.bytes for t in
             traffic_terms(Variant.C3B2A0, mk, blk, p, policy="analytic")}
    for name, b in walked.items():
        assert terms[name] == pytest.approx(b, rel=1e-9), name


def test_b3c2a0_closed_form_exact_when_divisible():
    p = Problem(48, 96, 64)
    mk = MicroKernel(4, 8)
    blk = Blocking(m_c=24, n_c=48, k_c=32)
    walked = _walk_b3c2a0(mk, blk, p)
    terms = {t.name: t.bytes for t in
             traffic_terms(Variant.B3C2A0, mk, blk, p, policy="analytic")}
    for name, b in walked.items():
        assert terms[name] == pytest.approx(b, rel=1e-9), name


@pytest.mark.parametrize("variant,walker", [
    (Variant.C3B2A0, _walk_c3b2a0), (Variant.B3C2A0, _walk_b3c2a0)])
@pytest.mark.parametrize("dims", [(256, 784, 2304), (100, 60, 250)])
def test_a_resident_closed_forms_sandwich_walk(variant, walker, dims):
    m, n, k = dims
    p = Problem(m, n, k)
    mk = MicroKernel(4, 8)
    blk = derive_blocking(variant, mk, GAP8_FC, p)
    walked = walker(mk, blk, p)
    lo = {t.name: t.bytes for t in
          traffic_terms(variant, mk, blk, p, policy="analytic")}
    hi = {t.name: t.bytes for t in
          traffic_terms(variant, mk, blk, p, policy="padded")}
    for name, b in walked.items():
        assert lo[name] <= b * (1 + 1e-9), name
        assert b <= hi[name] * 1.25 + 1e-9, name


# ---------------------------------------------------------------------------
# Simulator behaviour
# ---------------------------------------------------------------------------

def test_total_is_sum_of_components():
    cb = simulate(GAP8_FC, Variant.B3A2C0, MicroKernel(4, 24), LAYER10)
    assert cb.total == pytest.approx(sum(cb.components.values()))
    assert cb.arith == pytest.approx(LAYER10.flops / 5.64e9)


def test_arith_independent_of_microkernel():
    """Paper §4: the basic simulator's arithmetic cost is micro-kernel
    independent."""
    t = [simulate(GAP8_FC, Variant.B3A2C0, mk, LAYER10).arith
         for mk in feasible_microkernels(GAP8_FC, Variant.B3A2C0)]
    assert max(t) == pytest.approx(min(t))


def test_packing_rate_chunk_scaling():
    """Paper §3.2: n_r=4 -> 1.62 MB/s, n_r=8 -> 3.24 MB/s."""
    assert GAP8_FC.packing_rate("M", "M", 4) == pytest.approx(1.62e6)
    assert GAP8_FC.packing_rate("M", "M", 8) == pytest.approx(3.24e6)


def test_paper_headline_b3a2c0_low_and_fat():
    """Paper §4: B3A2C0 favours low-and-fat micro-kernels (4x24) on layer 10."""
    cb = best_microkernel(GAP8_FC, Variant.B3A2C0, LAYER10)
    assert (cb.micro_kernel.rows, cb.micro_kernel.cols) == (4, 24)


def test_paper_headline_b3c2a0_low_and_fat():
    cb = best_microkernel(GAP8_FC, Variant.B3C2A0, LAYER10)
    assert (cb.micro_kernel.rows, cb.micro_kernel.cols) == (4, 24)


def test_paper_headline_c3b2a0_not_low_and_fat():
    """Paper §4: C3B2A0 prefers 'squarish' (8x12/12x8) or tall (24x4)
    kernels on layer 10 — never the low-and-fat 4x24."""
    cb = best_microkernel(GAP8_FC, Variant.C3B2A0, LAYER10)
    assert (cb.micro_kernel.rows, cb.micro_kernel.cols) in {(8, 12), (12, 8), (24, 4)}


def test_table2_agreement_rate():
    """Exact micro-kernel agreement with Table 2.  The paper under-specifies
    partial-tile/rounding policy; we require the headline agreement levels
    documented in EXPERIMENTS.md (and fail if a change regresses them)."""
    agree = {v: 0 for v in Variant}
    for row in TABLE2:
        for v in Variant:
            cb = best_microkernel(GAP8_FC, v, row.problem)
            mk = row.best[v.value]
            if (cb.micro_kernel.rows, cb.micro_kernel.cols) == (mk.rows, mk.cols):
                agree[v] += 1
    assert agree[Variant.B3A2C0] >= 13
    assert agree[Variant.B3C2A0] >= 16
    assert agree[Variant.C3B2A0] >= 7
    assert sum(agree.values()) >= 36


def test_fig6_b3a2c0_generally_fastest():
    """Paper §4 (Fig. 6): 'a general advantage of the B3A2C0 variant' —
    it must win the majority of total MobileNetV1 time."""
    totals = {v: 0.0 for v in Variant}
    wins = {v: 0 for v in Variant}
    for row in TABLE2:
        best = {v: best_microkernel(GAP8_FC, v, row.problem).total for v in Variant}
        totals = {v: totals[v] + best[v] for v in Variant}
        wins[min(best, key=best.get)] += 1
    assert totals[Variant.B3A2C0] == min(totals.values())
    assert wins[Variant.B3A2C0] == max(wins.values())


def test_trip_counts_are_integral():
    mk = MicroKernel(4, 8)
    blk = derive_blocking(Variant.B3A2C0, mk, GAP8_FC, LAYER10)
    trips = loop_trip_counts(Variant.B3A2C0, mk, blk, LAYER10)
    assert all(isinstance(v, int) and v >= 1 for v in trips.values())


# ---------------------------------------------------------------------------
# Property tests (hypothesis): simulator invariants
# ---------------------------------------------------------------------------

dims = st.integers(min_value=8, max_value=2048)


@settings(max_examples=40, deadline=None)
@given(m=dims, n=dims, k=dims)
def test_costs_positive_and_monotone_in_flops(m, n, k):
    p = Problem(m, n, k)
    p2 = Problem(2 * m, n, k)
    for v in Variant:
        mk = MicroKernel(4, 8)
        c1 = simulate(GAP8_FC, v, mk, p)
        c2 = simulate(GAP8_FC, v, mk, p2)
        assert c1.total > 0
        assert all(x >= 0 for x in c1.components.values())
        # doubling m never makes the GEMM cheaper
        assert c2.total >= c1.total


@settings(max_examples=40, deadline=None)
@given(m=dims, n=dims, k=dims)
def test_traffic_bytes_at_least_compulsory(m, n, k):
    """Every variant must move at least the compulsory traffic: read A and B
    once, write C once."""
    p = Problem(m, n, k)
    for v in Variant:
        cb = simulate(GAP8_FC, v, MicroKernel(4, 8), p)
        total_bytes = sum(cb.traffic_bytes.values())
        assert total_bytes >= p.abytes + p.bbytes + p.cbytes


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=dims)
def test_blocking_fits_scratchpads(m, n, k):
    p = Problem(m, n, k)
    for v in Variant:
        for mk in (MicroKernel(4, 8), MicroKernel(8, 12), MicroKernel(24, 4)):
            blk = derive_blocking(v, mk, GAP8_FC, p)
            l1, l2 = GAP8_FC.capacity("L1"), GAP8_FC.capacity("L2")
            if v is Variant.B3A2C0:
                assert blk.k_c * mk.cols <= l1 or blk.k_c == 1
                assert blk.m_c * blk.k_c <= l2 or blk.m_c == mk.rows
            elif v is Variant.C3B2A0:
                assert mk.rows * blk.n_c <= l1 or blk.n_c == 1
                assert blk.k_c * blk.n_c <= l2 or blk.k_c == 1
            else:
                assert mk.cols * blk.n_c <= l1 or blk.n_c == 1
                assert blk.m_c * blk.n_c <= l2 or blk.m_c == mk.rows
