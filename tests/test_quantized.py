"""Weight-only int8 serving quantization + calibration-methodology tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.common import HOST_MESH, split_params
from repro.models.model import LM
from repro.runtime.quantized import (
    QuantizedTensor,
    dequantize_params,
    quantization_error,
    quantize_params,
)


def test_quantize_roundtrip_error_bounded():
    lm = LM(get_config("qwen2-1.5b", smoke=True), HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    errs = quantization_error(values)
    assert errs, "expected at least one quantised leaf"
    assert max(errs.values()) < 1.0 / 127 + 1e-3   # per-channel symmetric


def test_small_tensors_not_quantized():
    tree = {"norm": jnp.ones((64,)), "w": jnp.ones((256, 256))}
    q = quantize_params(tree, min_size=1 << 10)
    assert not isinstance(q["norm"], QuantizedTensor)
    assert isinstance(q["w"], QuantizedTensor)
    assert q["w"].q.dtype == jnp.int8


def test_quantized_decode_logits_close_to_fp():
    """Decode logits with int8 weights stay close to the fp logits."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(1)))
    vq = dequantize_params(quantize_params(values, min_size=1 << 10),
                           jnp.dtype(cfg.compute_dtype))

    def logits_seq(vals):
        caches, _ = split_params(lm.init_cache(1, 16))
        out = []
        for t, tok in enumerate([3, 7, 11, 2, 5]):
            lg, caches = lm.decode_step(vals, caches,
                                        jnp.array([[tok]], jnp.int32),
                                        jnp.int32(t))
            out.append(lg.astype(jnp.float32)[..., :cfg.vocab_size])
        return jnp.stack(out)

    fp = logits_seq(values)
    q = logits_seq(vq)
    scale = float(jnp.max(jnp.abs(fp))) + 1e-6
    assert float(jnp.max(jnp.abs(fp - q))) / scale < 0.15


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_quantization_per_channel_scales(seed):
    rng = np.random.default_rng(seed)
    # rows with wildly different magnitudes: per-channel scales must adapt
    w = jnp.array(rng.normal(size=(256, 128)) *
                  (10.0 ** rng.integers(-3, 3, size=(256, 1))), jnp.float32)
    qt = quantize_params({"w": w}, min_size=1)["w"]
    back = qt.q.astype(jnp.float32) * qt.scale
    rel = np.abs(np.asarray(back - w)) / (np.abs(np.asarray(w)) + 1e-9)
    # elements at >= 1% of their row max are accurate to ~1%
    row_max = np.abs(np.asarray(w)).max(axis=1, keepdims=True)
    big = np.abs(np.asarray(w)) > 0.01 * row_max
    assert rel[big].max() < 0.5


def test_calibration_methodology_runs():
    """Paper §3.2 methodology on this host: rates positive, packing rate
    roughly monotone in chunk size (the paper's linearity claim, loosely)."""
    from repro.core.calibrate import calibrate_host, measure_packing_rate
    spec = calibrate_host()
    assert spec.arith_rate["int8"] > 0
    r4 = measure_packing_rate(4, rows=512, cols=512)
    r32 = measure_packing_rate(32, rows=512, cols=512)
    assert r32 > r4 * 1.2          # bigger chunks pack faster
