"""Weight-only int8 serving quantization + calibration-methodology tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.common import HOST_MESH, split_params
from repro.models.model import LM
from repro.runtime.quantized import (
    QuantizedTensor,
    dequantize_params,
    quantization_error,
    quantize_params,
    quantized_specs,
)


def test_quantize_roundtrip_error_bounded():
    lm = LM(get_config("qwen2-1.5b", smoke=True), HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    errs = quantization_error(values)
    assert errs, "expected at least one quantised leaf"
    assert max(errs.values()) < 1.0 / 127 + 1e-3   # per-channel symmetric


def test_small_tensors_not_quantized():
    tree = {"norm": jnp.ones((64,)), "w": jnp.ones((256, 256))}
    q = quantize_params(tree, min_size=1 << 10)
    assert not isinstance(q["norm"], QuantizedTensor)
    assert isinstance(q["w"], QuantizedTensor)
    assert q["w"].q.dtype == jnp.int8


def test_quantized_decode_logits_close_to_fp():
    """Decode logits with int8 weights stay close to the fp logits."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(1)))
    vq = dequantize_params(quantize_params(values, min_size=1 << 10),
                           jnp.dtype(cfg.compute_dtype))

    def logits_seq(vals):
        caches, _ = split_params(lm.init_cache(1, 16))
        out = []
        for t, tok in enumerate([3, 7, 11, 2, 5]):
            lg, caches = lm.decode_step(vals, caches,
                                        jnp.array([[tok]], jnp.int32),
                                        jnp.int32(t))
            out.append(lg.astype(jnp.float32)[..., :cfg.vocab_size])
        return jnp.stack(out)

    fp = logits_seq(values)
    q = logits_seq(vq)
    scale = float(jnp.max(jnp.abs(fp))) + 1e-6
    assert float(jnp.max(jnp.abs(fp - q))) / scale < 0.15


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_quantization_per_channel_scales(seed):
    rng = np.random.default_rng(seed)
    # rows with wildly different magnitudes: per-channel scales must adapt
    w = jnp.array(rng.normal(size=(256, 128)) *
                  (10.0 ** rng.integers(-3, 3, size=(256, 1))), jnp.float32)
    qt = quantize_params({"w": w}, min_size=1)["w"]
    back = qt.q.astype(jnp.float32) * qt.scale
    rel = np.abs(np.asarray(back - w)) / (np.abs(np.asarray(w)) + 1e-9)
    # elements at >= 1% of their row max are accurate to ~1%
    row_max = np.abs(np.asarray(w)).max(axis=1, keepdims=True)
    big = np.abs(np.asarray(w)) > 0.01 * row_max
    assert rel[big].max() < 0.5


def test_quantized_specs_mirror_quantize_params_structure():
    """The sharding-spec tree must have the same treedef as the quantised
    value tree, or jit donation/sharding silently misaligns: every leaf
    quantize_params converts must become a QuantizedTensor spec node, and
    the scale's spec must shard axis 0 with the data (trailing axes are
    keepdims=1, so replicated)."""
    from jax.sharding import PartitionSpec as P

    values = {
        "w": jnp.ones((256, 128), jnp.float32),        # quantised
        "emb": jnp.ones((512, 64), jnp.bfloat16),      # quantised
        "norm": jnp.ones((64,), jnp.float32),          # too small / 1-D
        "q_proj": jnp.ones((8, 64, 64), jnp.float32),  # 3-D, quantised
        "ids": jnp.ones((256, 128), jnp.int32),        # not floating
    }
    specs = {
        "w": P("data", None),
        "emb": P(None, "model"),
        "norm": P(None),
        "q_proj": P("model", None, None),
        "ids": P("data", None),
    }
    qv = quantize_params(values, min_size=1 << 12)
    qs = quantized_specs(values, specs)
    # structural agreement leaf-for-leaf with the value tree
    assert jax.tree.structure(qv) == jax.tree.structure(
        qs, is_leaf=lambda x: isinstance(x, P))
    for name in ("w", "emb", "q_proj"):
        assert isinstance(qv[name], QuantizedTensor)
        assert isinstance(qs[name], QuantizedTensor)
        assert qs[name].q == specs[name]               # data keeps its spec
    assert qs["w"].scale == P("data", None)
    assert qs["emb"].scale == P(None, None)            # axis0 spec was None
    assert qs["q_proj"].scale == P("model", None, None)
    # passthrough leaves keep their original specs untouched
    assert qs["norm"] == specs["norm"]
    assert qs["ids"] == specs["ids"]


def test_quantized_specs_threshold_matches_quantize_params_default():
    """quantized_specs hardcodes the 1<<14 default threshold — a tensor just
    under it must stay a plain spec while one at it becomes quantised, in
    lockstep with quantize_params(min_size=1<<14)."""
    from jax.sharding import PartitionSpec as P

    small = jnp.ones((128, 127), jnp.float32)          # 16256 < 1<<14
    large = jnp.ones((128, 128), jnp.float32)          # 16384 == 1<<14
    values = {"small": small, "large": large}
    specs = {"small": P("x", None), "large": P("x", None)}
    qv = quantize_params(values)
    qs = quantized_specs(values, specs)
    assert not isinstance(qv["small"], QuantizedTensor)
    assert not isinstance(qs["small"], QuantizedTensor)
    assert isinstance(qv["large"], QuantizedTensor)
    assert isinstance(qs["large"], QuantizedTensor)


def test_quantized_specs_on_real_model_params():
    """Every quantised leaf of a real parameter tree gets a QuantizedTensor
    spec whose scale shape broadcasts against the data."""
    lm = LM(get_config("qwen2-1.5b", smoke=True), HOST_MESH)
    values, specs = split_params(lm.init(jax.random.key(0)))
    qv = quantize_params(values)
    qs = quantized_specs(values, specs)
    flat_v = dict(jax.tree_util.tree_leaves_with_path(
        qv, is_leaf=lambda x: isinstance(x, QuantizedTensor)))
    flat_s = dict(jax.tree_util.tree_leaves_with_path(
        qs, is_leaf=lambda x: isinstance(x, QuantizedTensor)))
    assert set(flat_v) == set(flat_s)
    n_qt = 0
    for path, v in flat_v.items():
        s = flat_s[path]
        assert isinstance(s, QuantizedTensor) == isinstance(v, QuantizedTensor)
        if isinstance(v, QuantizedTensor):
            n_qt += 1
            assert len(s.scale) == v.q.ndim            # one entry per axis
            assert all(ax is None for ax in s.scale[1:])
    assert n_qt > 0


def test_calibration_methodology_runs():
    """Paper §3.2 methodology on this host: rates positive, packing rate
    roughly monotone in chunk size (the paper's linearity claim, loosely)."""
    from repro.core.calibrate import calibrate_host, measure_packing_rate
    spec = calibrate_host()
    assert spec.arith_rate["int8"] > 0
    r4 = measure_packing_rate(4, rows=512, cols=512)
    r32 = measure_packing_rate(32, rows=512, cols=512)
    assert r32 > r4 * 1.2          # bigger chunks pack faster
