"""Minimal stand-in for ``hypothesis`` used when the real package is absent.

The test suite's property tests only use ``@settings(max_examples=...,
deadline=...)``, ``@given(kwargs-only strategies)``, ``st.integers`` and
``st.sampled_from``.  This fallback replays each test on a deterministic
sample of the strategy space (boundary values first, then seeded pseudo-
random draws), so the suite still collects and exercises the properties in
environments where ``pip install hypothesis`` is not possible (e.g. the
offline container).  CI installs the real hypothesis from
``requirements-dev.txt`` and never loads this module.
"""
from __future__ import annotations

import inspect
import random
import types


class _Strategy:
    def __init__(self, draw, boundary):
        self._draw = draw          # rng -> value
        self._boundary = boundary  # deterministic edge values, tried first

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def boundary(self, i: int):
        return self._boundary[i % len(self._boundary)]


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     [min_value, max_value])


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements), elements)


strategies = types.SimpleNamespace(integers=integers,
                                   sampled_from=sampled_from)

_DEFAULT_MAX_EXAMPLES = 10
_N_BOUNDARY = 2  # examples drawn from strategy edges before random draws


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**named_strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                if i < _N_BOUNDARY:
                    drawn = {k: s.boundary(i)
                             for k, s in named_strategies.items()}
                else:
                    drawn = {k: s.draw(rng)
                             for k, s in named_strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example ({i + 1}/{n}): "
                        f"{fn.__qualname__}({drawn})") from e

        # Like real hypothesis: the wrapped test takes no arguments, so
        # pytest does not mistake the strategy names for fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


def install() -> types.ModuleType:
    """Register this fallback as the importable ``hypothesis`` module."""
    import sys
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.__doc__ = __doc__
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies  # type: ignore
    return mod
