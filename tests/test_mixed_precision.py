"""Mixed-precision GEMM zoo: PrecisionConfig semantics, quantize-traffic
attribution, batched-vs-scalar bit-identity under per-operand dtypes, the
``rates_mixed`` machine schema, the sweep/deployment precision axis, and
mixed-key calibration.

The two load-bearing properties:

* every *uniform* PrecisionConfig normalizes to the pre-existing
  single-dtype path **bit-identically** (Table-2 totals ``==``, same
  micro-kernel picks, same plan-cache identity);
* every *mixed* config's batched engine agrees **bit-identically** with the
  scalar simulator, with each ``quant_*`` component exactly ``ratio x`` its
  base term's seconds.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import gemm, machines
from repro.core.mobilenet import TABLE2
from repro.core.precision import (
    DEFAULT_ACC,
    DTYPE_BITS,
    OPERAND_DTYPES,
    PrecisionConfig,
)
from repro.core.simulator import (
    best_microkernel_batch,
    best_microkernel_scalar,
    simulate,
)
from repro.core.variants import Variant, quant_ratio_map
from repro.gemm.api import GemmProblem
from repro.machines.spec import SpecValidationError


@pytest.fixture(autouse=True)
def _fresh_cache():
    gemm.clear_plan_cache()
    yield
    gemm.clear_plan_cache()


# ---------------------------------------------------------------------------
# PrecisionConfig semantics
# ---------------------------------------------------------------------------


def test_precision_config_key_parse_roundtrip():
    pc = PrecisionConfig("f32", "int8")
    assert pc.acc_dtype == "int32"          # default follows compute dtype
    assert pc.compute_dtype == "int8"
    assert pc.key() == "f32xint8->int32"
    assert PrecisionConfig.parse(pc.key()) == pc
    assert PrecisionConfig.parse("int8xint8") == PrecisionConfig.uniform("int8")
    kv = PrecisionConfig.parse("bf16xint8->f32@kv=int8")
    assert kv.kv_dtype == "int8" and kv.acc_dtype == "f32"
    assert str(kv) == "bf16xint8->f32@kv=int8"
    assert PrecisionConfig.coerce(None) is None
    assert PrecisionConfig.coerce(kv) is kv
    assert PrecisionConfig.coerce("int4xint8") == PrecisionConfig("int4", "int8")


def test_precision_config_rejects_bad_input():
    with pytest.raises(ValueError, match="not an operand dtype"):
        PrecisionConfig("fp9", "int8")
    with pytest.raises(ValueError, match="not a\n? known dtype|not a known"):
        PrecisionConfig("int8", "int8", acc_dtype="int64")
    with pytest.raises(ValueError, match="kv_dtype"):
        PrecisionConfig("int8", "int8", kv_dtype="int64")
    with pytest.raises(ValueError, match="cannot parse"):
        PrecisionConfig.parse("int8+int8")
    with pytest.raises(TypeError):
        PrecisionConfig.coerce(42)


def test_uniform_detection_and_normalization():
    for dt in OPERAND_DTYPES:
        assert PrecisionConfig.uniform(dt).is_uniform
        # GemmProblem normalizes a uniform config to the literal plain path
        plain = GemmProblem.coerce((32, 48, 64), default_dtype=dt)
        via_pc = plain.with_precision(PrecisionConfig.uniform(dt))
        assert via_pc == plain and via_pc.precision is None
    assert not PrecisionConfig("int4", "int8").is_uniform
    # a non-default accumulator is NOT the existing path
    assert not PrecisionConfig("int8", "int8", acc_dtype="f32").is_uniform
    # a mixed config retags the problem with the compute dtype
    mixed = GemmProblem.coerce((32, 48, 64), default_dtype="int8") \
        .with_precision("f32xint8->int32")
    assert mixed.dtype == "int8" and mixed.precision.key() == "f32xint8->int32"


def test_quant_ratios_and_accuracy_proxy():
    ra, rb, rc = PrecisionConfig("f32", "int8").quant_ratios(1)
    assert (ra, rb, rc) == (3.0, 0.0, 3.0)     # f32 A + int32 acc over int8
    ra, rb, rc = PrecisionConfig("bf16", "int8").quant_ratios(1)
    assert (ra, rb, rc) == (1.0, 0.0, 3.0)
    # narrower-than-compute operands are never credited
    assert PrecisionConfig("int4", "int8").quant_ratios(1)[:2] == (0.0, 0.0)
    assert PrecisionConfig("int4", "int8").accuracy_proxy == 0.25
    assert PrecisionConfig("int8", "int8").accuracy_proxy == 0.5
    assert PrecisionConfig("bf16", "bf16").accuracy_proxy == 1.0
    assert PrecisionConfig("f32", "bf16").accuracy_proxy == 1.0


# ---------------------------------------------------------------------------
# Scalar simulator: quantize traffic attribution
# ---------------------------------------------------------------------------


def _mixed_problem(m, n, k, key, dtype="int8"):
    return GemmProblem.coerce((m, n, k), default_dtype=dtype) \
        .with_precision(key).as_problem()


@pytest.mark.parametrize("variant", list(Variant))
def test_quant_terms_are_exact_ratios_of_base_terms(variant):
    """Each quant_<term> charges exactly ratio x the base term's seconds
    (same route, same chunk) — the placement invariant of the cost model."""
    mach = machines.get("gap9-fc")
    prob = _mixed_problem(96, 80, 112, "f32xint8->int32")
    ratios = quant_ratio_map(prob)
    cb = best_microkernel_scalar(mach, variant, prob)
    quant = {n: s for n, s in cb.components.items()
             if n.startswith("quant_")}
    assert quant, "mixed config must emit quantize terms"
    for name, secs in quant.items():
        base = name[len("quant_"):]
        assert base in cb.components
        ratio = secs / cb.components[base]
        assert ratio == pytest.approx(max(ratios.values()), rel=1e-12) \
            or ratio == pytest.approx(min(r for r in ratios.values()
                                          if r > 0), rel=1e-12)
    assert cb.grouped()["quantize"] == pytest.approx(sum(quant.values()))
    # the plain-int8 plan has no quantize charges at all
    plain = best_microkernel_scalar(mach, variant,
                                    _mixed_problem(96, 80, 112, "int8xint8"))
    assert not any(n.startswith("quant_") for n in plain.components)
    assert plain.grouped()["quantize"] == 0.0


def test_mixed_arith_rate_resolution_chain():
    """rates_mixed key hit -> that rate; miss -> uniform compute-dtype rate."""
    gap9 = machines.get("gap9-fc")
    # table hit: the int4xint8 widening dot has its own calibrated rate
    assert gap9.arith_rate_mixed("int4xint8->int32", "int4") == \
        gap9.rates_mixed["int4xint8->int32"]
    # table miss: falls back to the uniform rate of the compute dtype
    gap8 = machines.get("gap8-fc")
    assert not gap8.rates_mixed
    assert gap8.arith_rate_mixed("f32xint8->int32", "int8") == \
        gap8.arith_rate["int8"]
    prob = _mixed_problem(64, 64, 64, "int4xint8->int32", dtype="int4")
    cb = simulate(gap9, Variant.B3A2C0, best_microkernel_scalar(
        gap9, Variant.B3A2C0, prob).micro_kernel, prob)
    assert cb.arith == pytest.approx(
        prob.flops / gap9.rates_mixed["int4xint8->int32"])


# ---------------------------------------------------------------------------
# Batched engine bit-identity (the property the batch engine claims)
# ---------------------------------------------------------------------------

_MIXED_KEYS = ["int8xint8", "int4xint8->int32", "f32xint8->int32",
               "bf16xint8->int32", "int4xint4->int32"]


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       key=st.sampled_from(_MIXED_KEYS),
       machine=st.sampled_from(["gap8-fc", "gap9-fc"]))
def test_batch_engine_bit_identical_under_mixed_precision(seed, key, machine):
    rng = np.random.default_rng(seed)
    mach = machines.get(machine)
    pc = PrecisionConfig.parse(key)
    if pc.compute_dtype not in mach.arith_rate \
            and pc.key() not in mach.rates_mixed:
        return  # machine cannot plan this config (no int4 path on gap8)
    probs = [_mixed_problem(int(rng.integers(1, 200)),
                            int(rng.integers(1, 200)),
                            int(rng.integers(1, 300)), key)
             for _ in range(4)]
    # mix plain problems into the same batch: zero-ratio quant rows must
    # not perturb them
    probs += [GemmProblem.coerce((int(rng.integers(1, 200)), 64, 64),
                                 default_dtype="int8").as_problem()]
    for variant in Variant:
        scalar = [best_microkernel_scalar(mach, variant, p) for p in probs]
        batch = best_microkernel_batch(mach, variant, probs)
        for s, b in zip(scalar, batch):
            assert s.total == b.total            # bit-identical, not approx
            assert s.micro_kernel == b.micro_kernel
            assert s.components == b.components


def test_tpu_batch_engine_matches_scalar_for_mixed():
    from repro.core.autotune import tune_batch, tune_scalar

    shapes = [GemmProblem.coerce((m, 2048, 1024), default_dtype="bf16")
              .with_precision("bf16xint8->f32").as_shape()
              for m in (8, 64, 256)]
    mach = machines.get("tpu-v5e")
    batch = tune_batch(shapes, machine=mach)
    for shape, d in zip(shapes, batch):
        s = tune_scalar(shape, True, mach)
        assert d.seconds == s.seconds
        assert d.tile == s.tile
        assert d.cost == s.cost
        assert d.cost.quant_bytes > 0


# ---------------------------------------------------------------------------
# Uniform configs are the existing dtype path, bit for bit (Table 2)
# ---------------------------------------------------------------------------


def test_uniform_precision_reproduces_table2_exactly():
    mach = machines.get("gap8-fc")
    uniform = PrecisionConfig.uniform("int8")
    for row in TABLE2:
        plain = row.problem
        via_pc = GemmProblem.coerce((row.m, row.n, row.k),
                                    default_dtype="int8") \
            .with_precision(uniform).as_problem()
        assert via_pc == plain
        for variant in Variant:
            a = best_microkernel_scalar(mach, variant, plain)
            b = best_microkernel_scalar(mach, variant, via_pc)
            assert a.total == b.total
            assert a.micro_kernel == b.micro_kernel


def test_uniform_precision_plans_share_cache_identity():
    """plan(precision=uniform) is literally the plain plan — same cache
    entry, same selection, same predicted seconds."""
    plain = gemm.plan((64, 96, 128), backend="analytic-gap8",
                      machine="gap8-fc", dtype="int8")
    via_pc = gemm.plan((64, 96, 128), backend="analytic-gap8",
                       machine="gap8-fc", precision="int8xint8->int32")
    assert via_pc is plain                      # identical cache hit
    stats = gemm.plan_cache_stats(reset=True)
    assert stats["hits"] >= 1


def test_explicit_dtype_override_clears_precision():
    p = GemmProblem.coerce((8, 8, 8), default_dtype="int8") \
        .with_precision("f32xint8->int32")
    q = GemmProblem.coerce(p, dtype="bf16")
    assert q.dtype == "bf16" and q.precision is None


# ---------------------------------------------------------------------------
# rates_mixed machine schema
# ---------------------------------------------------------------------------


def test_rates_mixed_roundtrip_scaled_and_fingerprint():
    base = machines.get("gap8-fc")
    spec = base.with_mixed_rates({"bf16xint8->int32": 2.5e9},
                                 name="gap8-mixed-test")
    assert spec.rates_mixed["bf16xint8->int32"] == 2.5e9
    back = type(spec).from_json(spec.to_json())
    assert back.rates_mixed == dict(spec.rates_mixed)
    assert back.fingerprint() == spec.fingerprint()
    # the table participates in the content fingerprint...
    other = base.with_mixed_rates({"bf16xint8->int32": 5.0e9},
                                  name="gap8-mixed-test")
    assert other.fingerprint() != spec.fingerprint()
    # ...but machines without one keep their pre-mixed identity: an empty
    # table is omitted from the manifest entirely
    assert "rates_mixed" not in base.to_json()
    # arithmetic scaling applies to mixed rates like any compute rate
    faster = spec.scaled(arith=2.0, name="gap8-mixed-2x")
    assert faster.rates_mixed["bf16xint8->int32"] == 5.0e9


def test_rates_mixed_validation():
    base = machines.get("gap8-fc")
    with pytest.raises(SpecValidationError, match="bad rates_mixed key"):
        base.with_mixed_rates({"int8+int8": 1e9})
    with pytest.raises(SpecValidationError, match="unknown dtype tag"):
        base.with_mixed_rates({"fp9xint8->int32": 1e9})
    with pytest.raises(SpecValidationError, match="positive finite"):
        base.with_mixed_rates({"int4xint8->int32": -1.0})


def test_unknown_arith_rate_dtype_raises_with_offending_key():
    """The validate() bugfix: unknown dtype tags in arith_rate used to be
    silently accepted (and then unreachable by any lookup)."""
    base = machines.get("gap8-fc")
    bad = dataclasses.replace(base, arith_rate={"int8": 1e9, "fp9": 1e9})
    with pytest.raises(SpecValidationError, match="fp9"):
        bad.validate()
    # every shipped zoo manifest passes the tightened check
    for name in machines.list_machines("zoo/*"):
        machines.get(name).validate()


# ---------------------------------------------------------------------------
# The sweep precision axis
# ---------------------------------------------------------------------------


def test_sweep_precisions_axis_tags_rows():
    res = gemm.sweep([(64, 96, 128)], backends=["analytic-gap8"],
                     machines=["gap9-fc"], dtypes=["int8"],
                     precisions=[None, "int8xint8", "f32xint8->int32"])
    tags = {r.precision for r in res.rows}
    assert tags == {None, "int8xint8->int32", "f32xint8->int32"}
    by_tag = {r.precision: r for r in res.rows}
    # uniform precision row is bit-identical to the plain dtype row
    assert by_tag["int8xint8->int32"].plan.predicted_seconds == \
        by_tag[None].plan.predicted_seconds
    # the mixed row pays quantize traffic on the same machine
    assert by_tag["f32xint8->int32"].plan.predicted_seconds > \
        by_tag[None].plan.predicted_seconds
    assert by_tag["f32xint8->int32"].as_dict()["precision"] == \
        "f32xint8->int32"


def test_plan_explain_attributes_quantize_terms():
    p = gemm.plan((64, 96, 128), backend="analytic-gap8", machine="gap9-fc",
                  precision="f32xint8->int32")
    ex = p.explain()
    quant = [t for t in ex["terms"] if t["kind"] == "quantize"]
    assert quant and all(t["seconds"] > 0 for t in quant)
    assert "f32xint8->int32" in ex["problem"]
    # TPU model: the quantize share is split out of the HBM stream
    pt = gemm.plan((256, 2048, 1024), backend="analytic-tpu",
                   machine="tpu-v5e", precision="bf16xint8->f32")
    ext = pt.explain()
    quant_t = [t for t in ext["terms"] if t["kind"] == "quantize"]
    assert len(quant_t) == 1 and quant_t[0]["seconds"] > 0


# ---------------------------------------------------------------------------
# Deployment ranking
# ---------------------------------------------------------------------------


def test_mixed_precision_changes_deployment_ranking():
    from repro.configs import get_config
    from repro.serving.report import plan_deployment

    cfg = get_config("qwen2-1.5b", smoke=True)
    report = plan_deployment(cfg, machines="gap9-fc", dtypes=("int8",),
                             batches=(1, 4), backend="analytic-gap8",
                             precisions=("int4xint8->int32",))
    mixed = {o.batch: o for o in report.options
             if o.precision == "int4xint8->int32"}
    plain = {o.batch: o for o in report.options if o.precision is None}
    assert set(mixed) == set(plain) == {1, 4}
    # at equal batch the widening-dot rate gain (rates_mixed 2.2e10 vs int8
    # 1.58e10 MAC/s) is outweighed by the int32-accumulator quantize
    # traffic, so the mixed cell is strictly slower — but its batch-4 cell
    # still outranks plain batch-1: the what-if interleaves into the table
    # rather than sorting to the bottom
    for b in (1, 4):
        assert mixed[b].tokens_per_second < plain[b].tokens_per_second
        assert mixed[b].accuracy_proxy == 0.25
    assert mixed[4].tokens_per_second > plain[1].tokens_per_second
    order = [(o.precision, o.batch) for o in report.options]
    assert order.index(("int4xint8->int32", 4)) < order.index((None, 1))
    # ...but select() never freezes a what-if mixed cell
    assert report.select().precision is None
    assert report.grid["precisions"] == ["int4xint8->int32"]
    d = mixed[4].as_dict()
    assert d["precision"] == "int4xint8->int32"
    assert d["accuracy_proxy"] == 0.25


def test_mixed_precision_cells_are_memory_pruned_with_reasons():
    from repro.configs import get_config
    from repro.serving.report import REJECT_WEIGHTS, plan_deployment

    cfg = get_config("qwen2-1.5b", smoke=False)
    tiny = (machines.get("gap9-fc")
            .with_capacities(M=10 * 2 ** 20, name="gap9-tinymem"))
    report = plan_deployment(cfg, machines=tiny, dtypes=("int8",),
                             batches=(1,), backend="analytic-gap8",
                             precisions=("bf16xint8->int32",))
    pc_rejects = [r for r in report.rejected
                  if r.dtype == "bf16xint8->int32"]
    assert pc_rejects and all(r.reason == REJECT_WEIGHTS
                              for r in pc_rejects)
    assert all(r.deficit_bytes > 0 for r in pc_rejects)


def test_slo_evaluation_prices_mixed_cells_but_never_deploys_one():
    """The SLO simulator must price a mixed cell under its PrecisionConfig
    (its dtype field is the 'AxB->ACC' label, not a plannable dtype) and
    keep it out of the deployable pool, mirroring report.select()."""
    from repro.configs import get_config
    from repro.serving.report import plan_deployment
    from repro.simulate import evaluate_deployment

    cfg = get_config("qwen2-1.5b", smoke=True)
    report = plan_deployment(cfg, machines="gap9-fc", dtypes=("int8",),
                             batches=(1, 2), backend="analytic-gap8",
                             precisions=("int4xint8->int32",))
    sel = evaluate_deployment(cfg, report, slo={"p99_latency_s": 30.0},
                              requests=30)
    assert sel.option.precision is None
    simulated = {r["dtype"] for r in sel.results}
    assert "int4xint8->int32" in simulated      # priced + in the table
    assert sel.option.dtype == "int8"


# ---------------------------------------------------------------------------
# Calibrator: mixed-key rate fitting
# ---------------------------------------------------------------------------


def _mixed_campaign(n=10):
    from repro.core.variants import MicroKernel

    shapes = [(32, 96, 64), (64, 48, 128), (96, 96, 96), (48, 160, 32),
              (128, 64, 64), (80, 80, 200), (40, 72, 88), (56, 120, 48),
              (104, 40, 152), (72, 88, 72)][:n]
    probs, mks = [], []
    for i, sh in enumerate(shapes):
        p = GemmProblem.coerce(sh, default_dtype="int8")
        if i % 2:
            p = p.with_precision("f32xint8->int32")
        probs.append(p)
        mks.append(MicroKernel(2 + (i % 3), 2 + ((i + 1) % 3)))
    return probs * 2, mks * 2


def test_calibrator_fits_mixed_rates_from_campaign():
    from repro.machines.calibrate import Calibrator

    truth = machines.get("gap9-fc")
    cal = Calibrator("gap9-fc", model="blis", policy="padded")
    probs, mks = _mixed_campaign()
    secs = [simulate(truth, cal.variant, mk, p.as_problem(),
                     policy="padded").total
            for p, mk in zip(probs, mks)]
    # the vectorized design matrix equals the scalar oracle exactly
    A, names = cal.design_matrix(probs, mks)
    As, names_s = cal.design_matrix_scalar(probs, mks)
    assert names == names_s
    np.testing.assert_array_equal(A, As)
    assert "arith:f32xint8->int32" in names and "arith:int8" in names

    spec, report = cal.fit(probs, secs, date=None, micro_kernels=mks,
                           name="gap9-refit")
    assert report.residual_rms_s < 1e-9
    assert spec.rates_mixed["f32xint8->int32"] == pytest.approx(
        truth.rates_mixed["f32xint8->int32"], rel=1e-6)
    assert spec.arith_rate["int8"] == pytest.approx(
        truth.arith_rate["int8"], rel=1e-6)


def test_calibrator_rejects_unsupported_mixed_combinations():
    from repro.machines.calibrate import Calibrator

    probs, mks = _mixed_campaign(4)
    cal = Calibrator("gap9-fc", model="blis", policy="padded")
    with pytest.raises(ValueError, match="per_mk_arith"):
        cal.design_matrix(probs, mks, per_mk_arith=True)
    pal = Calibrator("tpu-v5e", model="pallas")
    mixed_bf16 = [GemmProblem.coerce((64, 128, 64), default_dtype="bf16")
                  .with_precision("bf16xint8->f32")]
    with pytest.raises(ValueError, match="blis"):
        pal.design_matrix(mixed_bf16)
