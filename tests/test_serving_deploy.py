"""Memory-aware deployment planning: footprint model, feasibility pruning,
zoo-wide ranking, and the autoconfigure contract.

The acceptance properties of the memory-aware planner:

* no selected configuration's modelled footprint exceeds its machine's
  deployment-level budget;
* the batch is capped by the memory constraint *alone* (same grid without
  the constraint picks a larger batch);
* the zoo-wide pick is deterministic;
* infeasible cells carry machine-readable rejection reasons;
* legacy single-machine autoconfigure results are unchanged when memory is
  not binding.
"""
import pytest

from repro import machines
from repro.configs import get_config
from repro.serving.footprint import dtype_bytes, footprint
from repro.serving.report import (
    REJECT_FOOTPRINT,
    REJECT_KV_CACHE,
    REJECT_WEIGHTS,
    plan_deployment,
)

QWEN = "qwen2-1.5b"
REASONS = {REJECT_WEIGHTS, REJECT_KV_CACHE, REJECT_FOOTPRINT}


def _small_memory_machine(cfg, *, fits_batch, rejects_batch, max_len,
                          dtype="bf16", name="test-smallmem"):
    """A tpu-v5e derivative whose deployment budget sits strictly between
    the footprints of two batch sizes."""
    lo = footprint(cfg, batch=fits_batch, max_len=max_len, dtype=dtype)
    hi = footprint(cfg, batch=rejects_batch, max_len=max_len, dtype=dtype)
    assert lo.total_bytes < hi.total_bytes
    budget = (lo.total_bytes + hi.total_bytes) // 2
    return (machines.get("tpu-v5e")
            .with_memory(reserved_fraction=0.0)
            .with_capacities(M=budget, name=name))


# ---------------------------------------------------------------------------
# Footprint model
# ---------------------------------------------------------------------------


def test_footprint_scales_with_batch_len_and_dtype():
    cfg = get_config(QWEN, smoke=False)
    fp1 = footprint(cfg, batch=1, max_len=512, dtype="bf16")
    fp8 = footprint(cfg, batch=8, max_len=512, dtype="bf16")
    # weights are batch-independent; KV cache is linear in the slot count.
    assert fp8.weights_bytes == fp1.weights_bytes
    assert fp8.kv_cache_bytes == 8 * fp1.kv_cache_bytes
    # ... and linear in the cache length (qwen2 is all-attention).
    fp_long = footprint(cfg, batch=1, max_len=1024, dtype="bf16")
    assert fp_long.kv_cache_bytes == 2 * fp1.kv_cache_bytes
    # serving dtype scales the weight bytes.
    fp_int8 = footprint(cfg, batch=1, max_len=512, dtype="int8")
    assert fp_int8.weights_bytes * dtype_bytes("bf16") == \
        fp1.weights_bytes * dtype_bytes("int8")
    assert fp1.total_bytes == (fp1.weights_bytes + fp1.kv_cache_bytes
                               + fp1.activation_bytes)
    assert fp1.fits(fp1.total_bytes) and not fp1.fits(fp1.total_bytes - 1)


def test_footprint_covers_recurrent_and_moe_families():
    # every serving-relevant block kind yields positive, batch-scaling state
    for arch in ("zamba2-1.2b", "xlstm-125m", "granite-moe-3b-a800m"):
        cfg = get_config(arch, smoke=True)
        fp1 = footprint(cfg, batch=1, max_len=128)
        fp4 = footprint(cfg, batch=4, max_len=128)
        assert fp1.kv_cache_bytes > 0
        assert fp4.kv_cache_bytes == 4 * fp1.kv_cache_bytes


def test_footprint_honours_int8_kv_cache_config():
    import dataclasses
    cfg = get_config(QWEN, smoke=False)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    fp = footprint(cfg8, batch=2, max_len=512, dtype="bf16")
    assert fp.kv_dtype == "int8"
    # int8 panels + f32 scales must undercut the bf16 cache
    assert fp.kv_cache_bytes < \
        footprint(cfg, batch=2, max_len=512, dtype="bf16").kv_cache_bytes
    # an int8 *serving* what-if cell pays the same scale vectors the real
    # int8 cache allocates (models/attention.py), not the cfg default's
    fp_whatif = footprint(cfg, batch=2, max_len=512, dtype="int8")
    assert fp_whatif.kv_dtype == "int8"
    assert fp_whatif.kv_cache_bytes == fp.kv_cache_bytes


def test_footprint_rejects_bad_inputs():
    cfg = get_config(QWEN, smoke=True)
    with pytest.raises(ValueError):
        footprint(cfg, batch=0, max_len=512)
    with pytest.raises(KeyError):
        footprint(cfg, batch=1, max_len=512, dtype="fp4")


# ---------------------------------------------------------------------------
# Memory budget on MachineSpec
# ---------------------------------------------------------------------------


def test_memory_budget_and_manifest_round_trip():
    from repro.machines.spec import MachineSpec, SpecValidationError

    tpu = machines.get("tpu-v5e")
    assert tpu.memory_budget() == int(tpu.capacity("M") * 0.95)
    # the view follows level aliases / roles like every other accessor
    assert tpu.memory_budget("M") == tpu.memory_budget()
    derived = tpu.with_memory(reserved_fraction=0.5)
    assert derived.memory_budget() == int(tpu.capacity("M") * 0.5)
    assert derived.provenance["transform"]["with_memory"] == {
        "memory_reserved_fraction": 0.5}
    # memory section round-trips losslessly
    again = MachineSpec.from_json(derived.to_json())
    assert again.memory_reserved_fraction == 0.5
    assert again.to_json() == derived.to_json()
    # schema rejects nonsense
    with pytest.raises(SpecValidationError):
        tpu.with_memory(reserved_fraction=1.5)
    with pytest.raises(SpecValidationError):
        tpu.with_memory(deployment_level="L9")


# ---------------------------------------------------------------------------
# Sweep feasibility mask
# ---------------------------------------------------------------------------


def test_sweep_feasibility_mask_prunes_before_planning():
    from repro import gemm

    calls = []

    def mask(ma, dt):
        calls.append((ma, dt))
        return (dt != "int8", "int8 banned for test")

    res = gemm.sweep([(64, 64, 64), (64, 64, 64)], dtypes=["bf16", "int8"],
                     feasible=mask)
    assert {r.problem.dtype for r in res.rows} == {"bf16"}
    assert res.stats["pruned"] == 1 and len(res.pruned) == 1
    assert res.pruned[0]["reason"] == "int8 banned for test"
    assert res.pruned[0]["dtype"] == "int8"
    # the mask is consulted once per (machine, dtype), not per grid point
    assert len(calls) == 2
    assert "pruned" in res.to_json()


# ---------------------------------------------------------------------------
# plan_deployment: the memory constraint alone caps the batch
# ---------------------------------------------------------------------------


def test_batch_capped_by_kv_cache_capacity_alone():
    cfg = get_config(QWEN, smoke=False)
    max_len = 1024
    spec = _small_memory_machine(cfg, fits_batch=4, rejects_batch=8,
                                 max_len=max_len, name="test-kvcap")
    kwargs = dict(machines=spec, dtypes=("bf16",), batches=(1, 2, 4, 8, 16),
                  max_len=max_len)
    constrained = plan_deployment(cfg, **kwargs)
    free = plan_deployment(cfg, memory=False, **kwargs)
    # throughput alone wants the largest batch ...
    assert free.select().batch == 16 and not free.rejected
    # ... memory alone caps it at the largest batch that fits
    assert constrained.select().batch == 4
    # every surviving option's footprint fits the deployment budget
    assert constrained.options
    for o in constrained.options:
        assert o.footprint.total_bytes <= o.budget_bytes
        assert o.headroom_bytes >= 0
    # the over-budget batches were rejected before planning, for the KV
    # cache specifically (weights alone fit)
    rejected_batches = {r.batch for r in constrained.rejected}
    assert rejected_batches == {8, 16}
    for r in constrained.rejected:
        assert r.reason == REJECT_KV_CACHE
        assert r.deficit_bytes > 0
        d = r.as_dict()
        assert {"machine", "dtype", "batch", "reason", "footprint_bytes",
                "budget_bytes", "deficit_bytes"} <= set(d)


def test_weights_rejection_is_distinguished():
    cfg = get_config(QWEN, smoke=False)
    tiny = (machines.get("tpu-v5e")
            .with_memory(reserved_fraction=0.0)
            .with_capacities(M=10 * 2**20, name="test-tinymem"))
    report = plan_deployment(cfg, machines=tiny, dtypes=("bf16",),
                             batches=(1, 2), max_len=256)
    assert not report.options
    assert report.rejected and \
        all(r.reason == REJECT_WEIGHTS for r in report.rejected)
    with pytest.raises(ValueError, match="no feasible deployment"):
        report.best()


# ---------------------------------------------------------------------------
# int8 KV cache: exact scale-vector accounting + batch-capacity effect
# ---------------------------------------------------------------------------


def test_int8_kv_cache_scale_overhead_exact():
    """The int8 cache's byte accounting, term by term: per attention layer
    and slot, K+V panels at 1 byte/elem plus two f32 scale vectors
    (one per position per panel — models/attention.py stores per-position
    scales next to the quantised panels)."""
    cfg = get_config(QWEN, smoke=False)
    max_len = 512
    fp = footprint(cfg, batch=1, max_len=max_len, dtype="bf16",
                   kv_dtype="int8")
    panel = cfg.n_kv_heads * max_len * cfg.head_dim
    per_layer = 2 * panel * 1 + 2 * cfg.n_kv_heads * max_len * 4
    assert fp.kv_cache_bytes == cfg.n_layers * per_layer
    # bf16 cache for comparison: same panels at 2 bytes, no scales
    fp16 = footprint(cfg, batch=1, max_len=max_len, dtype="bf16")
    assert fp16.kv_cache_bytes == cfg.n_layers * 2 * panel * 2
    # the scale vectors cost head_dim/4 : 1 relative to the panel — int8
    # still roughly halves the cache for any realistic head_dim
    assert fp.kv_cache_bytes < fp16.kv_cache_bytes
    assert fp.as_dict()["kv_dtype"] == "int8"


def test_quantized_kv_cache_admits_larger_batch():
    """Budget sits between the bf16-KV and int8-KV footprints of batch 8:
    the quantised cache admits a batch the bf16 cache rejects, and the
    bf16 rejection is machine-readable (REJECT_KV_CACHE + deficit)."""
    cfg = get_config(QWEN, smoke=False)
    max_len = 1024
    fp_int8 = footprint(cfg, batch=8, max_len=max_len, dtype="bf16",
                        kv_dtype="int8")
    fp_bf16 = footprint(cfg, batch=8, max_len=max_len, dtype="bf16")
    assert fp_int8.total_bytes < fp_bf16.total_bytes
    budget = (fp_int8.total_bytes + fp_bf16.total_bytes) // 2
    spec = (machines.get("tpu-v5e")
            .with_memory(reserved_fraction=0.0)
            .with_capacities(M=budget, name="test-kvdtype"))
    kwargs = dict(machines=spec, dtypes=("bf16",), batches=(1, 8),
                  max_len=max_len)
    plain = plan_deployment(cfg, **kwargs)
    quant = plan_deployment(cfg, kv_dtype="int8", **kwargs)
    assert plain.select().batch == 1
    assert {r.batch for r in plain.rejected} == {8}
    assert all(r.reason == REJECT_KV_CACHE and r.deficit_bytes > 0
               for r in plain.rejected)
    # the int8 cache halves the KV bytes: batch 8 now fits and wins
    assert quant.select().batch == 8
    assert not quant.rejected
    assert quant.select().footprint.kv_dtype == "int8"


def test_precision_kv_dtype_flows_into_footprint():
    """A PrecisionConfig's ``@kv=int8`` tag prices its deployment cells
    with the quantised cache: same bf16 weights and GEMM costs as the base
    bf16 cell, but the cache bytes drop — so with a budget between the two
    footprints, only the precision cell survives."""
    cfg = get_config(QWEN, smoke=False)
    max_len = 1024
    fp_kv8 = footprint(cfg, batch=8, max_len=max_len, dtype="bf16",
                       kv_dtype="int8")
    fp_kv16 = footprint(cfg, batch=8, max_len=max_len, dtype="bf16")
    assert fp_kv8.total_bytes < fp_kv16.total_bytes
    budget = (fp_kv8.total_bytes + fp_kv16.total_bytes) // 2
    spec = (machines.get("tpu-v5e")
            .with_memory(reserved_fraction=0.0)
            .with_capacities(M=budget, name="test-kvprec"))
    report = plan_deployment(
        cfg, machines=spec, dtypes=("bf16",), batches=(8,), max_len=max_len,
        precisions=("bf16xbf16->f32@kv=int8",))
    # the base bf16 cell's bf16 cache blows the budget ...
    assert [r.dtype for r in report.rejected] == ["bf16"]
    assert report.rejected[0].reason == REJECT_KV_CACHE
    # ... while the @kv=int8 what-if (identical weights + GEMM plan) fits
    assert len(report.options) == 1
    opt = report.options[0]
    assert opt.precision == "bf16xbf16->f32"   # key() carries the GEMM part
    assert opt.footprint.kv_dtype == "int8"
    assert opt.footprint.total_bytes == fp_kv8.total_bytes
    assert opt.batch == 8 and opt.headroom_bytes >= 0


# ---------------------------------------------------------------------------
# Zoo-wide ranking
# ---------------------------------------------------------------------------


def test_zoo_wide_pick_is_deterministic_and_ranked():
    cfg = get_config(QWEN, smoke=True)
    kwargs = dict(machines="zoo/*", dtypes=("bf16", "int8"),
                  batches=(1, 4), max_len=64)
    a = plan_deployment(cfg, **kwargs)
    b = plan_deployment(cfg, **kwargs)
    key = lambda o: (o.machine, o.dtype, o.batch)  # noqa: E731
    assert [key(o) for o in a.options] == [key(o) for o in b.options]
    assert key(a.select()) == key(b.select())
    # ranked: non-increasing predicted throughput
    tps = [o.tokens_per_second for o in a.options]
    assert tps == sorted(tps, reverse=True)
    # the grid really spanned the registry's manifests
    assert set(a.grid["machines"]) == set(machines.list_machines("zoo/*"))
    # per-machine view preserves rank order and covers only feasible ones
    pm = a.per_machine_best()
    assert list(pm) == [m for m in dict.fromkeys(o.machine
                                                 for o in a.options)]
    # reasons (if any cell was pruned) are machine-readable codes
    assert {r.reason for r in a.rejected} <= REASONS
    # report serializes
    j = a.to_json()
    assert j["options"] and j["model"] == cfg.name
    assert a.table(limit=3)


# ---------------------------------------------------------------------------
# Engine-level autoconfigure
# ---------------------------------------------------------------------------


def _smoke_lm():
    import jax
    from repro.models.common import HOST_MESH, split_params
    from repro.models.model import LM

    cfg = get_config(QWEN, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    return lm, values


def test_autoconfigure_batch_reduced_by_memory_constraint():
    from repro.serving.engine import Request, ServingEngine

    lm, values = _smoke_lm()
    max_len = 64
    spec = _small_memory_machine(lm.cfg, fits_batch=1, rejects_batch=4,
                                 max_len=max_len, name="test-engine-mem")
    free = ServingEngine.autoconfigure(lm, values, machine=spec,
                                       dtypes=("bf16",), batches=(1, 4),
                                       max_len=max_len, memory=False)
    eng = ServingEngine.autoconfigure(lm, values, machine=spec,
                                      dtypes=("bf16",), batches=(1, 4),
                                      max_len=max_len)
    # the memory constraint alone reduced the chosen batch
    assert free.max_batch == 4
    assert eng.max_batch == 1
    ac = eng.autoconfig
    assert ac["max_batch"] == 1 and ac["memory_headroom_bytes"] >= 0
    assert [r["reason"] for r in ac["rejected"]] == [REJECT_KV_CACHE]
    # the ranked report rides on the engine, selection consistent with it
    rep = eng.deployment_report
    assert rep.select().batch == 1
    assert all(o.footprint.total_bytes <= o.budget_bytes
               for o in rep.options)
    # the constrained engine still serves
    eng.submit(Request(rid=0, prompt=[3, 1, 4], max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 1 and len(done[0].generated) == 3


def test_autoconfigure_unchanged_when_memory_not_binding():
    from repro.serving.engine import ServingEngine

    lm, values = _smoke_lm()
    kwargs = dict(dtypes=("bf16", "int8"), batches=(1, 4), max_len=64)
    eng = ServingEngine.autoconfigure(lm, values, **kwargs)
    legacy = ServingEngine.autoconfigure(lm, values, memory=False, **kwargs)
    # smoke model vs 16 GB HBM: nothing is pruned, and the pick matches the
    # legacy throughput-only grid exactly
    assert eng.autoconfig["rejected"] == []
    for key in ("max_batch", "machine", "dtype",
                "predicted_tokens_per_second"):
        assert eng.autoconfig[key] == legacy.autoconfig[key]
    assert eng.max_batch == legacy.max_batch
    assert [p.describe() for p in eng.gemm_plans] == \
        [p.describe() for p in legacy.gemm_plans]
