"""repro.design: templates, spaces, frontier, grounding, gen/ namespace."""
import dataclasses

import numpy as np
import pytest

from repro import design, gemm, machines
from repro.design import (
    AcceleratorTemplate,
    DesignPoint,
    DesignScore,
    DesignSpace,
    get_space,
    pareto,
    score_designs,
    template_of,
)
from repro.machines.spec import MachineSpec, SpecValidationError
from repro.measure.campaign import grid_problems


@pytest.fixture(autouse=True)
def _clean_registry():
    before = set(machines.list_machines())
    yield
    machines.unregister_prefix("gen/")
    for name in set(machines.list_machines()) - before:
        machines.unregister(name)


# -- template expansion --------------------------------------------------------


def test_expand_is_valid_and_roundtrips():
    spec = AcceleratorTemplate().expand()
    spec.validate()
    back = MachineSpec.from_json(spec.to_json())
    assert back == spec
    assert back.fingerprint() == spec.fingerprint()
    # provenance records the generator and the full parameter set
    assert spec.provenance["generator"] == "repro.design/v1"
    assert spec.provenance["template"]["lanes"] == 8
    # and the template is recoverable from it
    tpl = template_of(spec)
    assert tpl.expand() == spec


def test_expand_is_deterministic_and_content_addressed():
    a = AcceleratorTemplate(lanes=4)
    b = AcceleratorTemplate(lanes=4)
    assert a.expand() == b.expand()
    assert a.name == b.name and a.name.startswith("gen/")
    # different parameters, different identity
    assert a.name != AcceleratorTemplate(lanes=16).name


def test_expand_derivation_rules():
    tpl = AcceleratorTemplate(lanes=8, mac_units=2, frequency_hz=370e6,
                              pack_bw=3.24e6, dma_bw=1.76e7, noc_bw=1.44e7,
                              reg_bytes_per_cycle=0.96)
    spec = tpl.expand()
    assert spec.arith_rate["int8"] == pytest.approx(2 * 2 * 8 * 370e6)
    assert spec.rate("M", "L1") == pytest.approx(1.76e7)
    assert spec.rate("L2", "R") == pytest.approx(1.44e7)
    assert spec.rate("L1", "R") == pytest.approx(0.96 * 370e6)
    assert spec.rate("M", "M") == pytest.approx(3.24e6)
    assert spec.rate("M", "L2") == pytest.approx(0.33 * 3.24e6)
    assert spec.capacity("R") == 32 * 8  # regs x lanes x elem_bytes
    assert spec.register_lanes == 8


def test_template_validates_parameters():
    with pytest.raises(ValueError):
        AcceleratorTemplate(lanes=0)
    with pytest.raises(ValueError):
        AcceleratorTemplate(dma_bw=-1.0)


def test_bandwidth_scaling_never_hurts_table2_throughput():
    """Property: 2x every bandwidth -> total modelled Table-2 time never
    increases (every transfer term is monotone in its rate; compute terms
    unchanged; re-search can only improve the winner)."""
    base = AcceleratorTemplate()
    fast = base.scaled_bandwidth(2.0)
    probs = grid_problems("table2", dtype="int8")
    t_base = {r.problem: r.seconds for r in gemm.sweep(
        probs, machines=[base.expand()],
        backends=["analytic-gap8"]).rows}
    t_fast = {r.problem: r.seconds for r in gemm.sweep(
        probs, machines=[fast.expand()],
        backends=["analytic-gap8"]).rows}
    assert set(t_base) == set(t_fast) and t_base
    for p, s in t_base.items():
        assert t_fast[p] <= s + 1e-15


# -- registry namespace --------------------------------------------------------


def test_gen_namespace_and_bulk_unregister():
    names = get_space("smoke").register_all()
    assert len(names) == 8
    assert all(n.startswith("gen/") for n in names)
    assert machines.list_machines("gen/*") == sorted(names)
    assert machines.source_of(names[0]) == "generated"
    # zoo globs are unaffected by the gen/ names
    assert not [n for n in machines.list_machines("zoo/*")
                if n.startswith("gen/")]
    dropped = machines.unregister_prefix("gen/")
    assert dropped == sorted(names)
    assert machines.list_machines("gen/*") == []
    with pytest.raises(ValueError):
        machines.unregister_prefix("")


def test_spec_names_allow_one_namespace_slash():
    spec = AcceleratorTemplate().expand()
    spec.validate()  # gen/<id> passes
    for bad in ("gen/", "/x", "a/b/c", "a /b"):
        with pytest.raises(SpecValidationError):
            dataclasses.replace(spec, name=bad).validate()


def test_repeated_glob_sweeps_identically_ordered():
    """Regression: glob expansion is sorted, so two identical sweeps
    return rows in the same order."""
    get_space("smoke").register_all(limit=4)
    probs = grid_problems("smoke", dtype="int8")[:3]
    r1 = gemm.sweep(probs, machines="gen/*", backends=["analytic-gap8"])
    r2 = gemm.sweep(probs, machines="gen/*", backends=["analytic-gap8"])
    key = lambda r: (r.machine, r.problem, r.seconds, str(r.selection))
    assert [key(r) for r in r1.rows] == [key(r) for r in r2.rows]


def test_glob_sweep_bit_identical_to_eager_specs():
    """Acceptance: machines="gen/*" plans generated specs bit-identically
    to eagerly expanded spec objects."""
    space = get_space("smoke")
    space.register_all(limit=4)
    eager = [space.point(i).spec() for i in range(4)]
    eager.sort(key=lambda s: s.name)        # glob order is sorted
    probs = grid_problems("smoke", dtype="int8")[:3]
    lazy_rows = gemm.sweep(probs, machines="gen/*",
                           backends=["analytic-gap8"]).rows
    eager_rows = gemm.sweep(probs, machines=eager, cache=False,
                            backends=["analytic-gap8"]).rows
    assert len(lazy_rows) == len(eager_rows) == 4 * 3
    for a, b in zip(lazy_rows, eager_rows):
        assert a.machine == b.machine
        assert a.problem == b.problem
        assert a.seconds == b.seconds       # bit-identical, not approx
        assert str(a.selection) == str(b.selection)


# -- design spaces -------------------------------------------------------------


def test_space_indexing_and_lazy_iteration():
    space = get_space("gap9-sweep")
    assert len(space) == 64
    pts = list(space.points())
    assert [p.index for p in pts] == list(range(64))
    # row-major: last axis fastest
    assert pts[0].params["dma_bw"] != pts[1].params["dma_bw"]
    assert pts[0].params["lanes"] == pts[1].params["lanes"]
    # indexed access matches iteration
    assert space.point(17).template == pts[17].template
    with pytest.raises(IndexError):
        space.point(64)


def test_space_rejects_unknown_axis():
    with pytest.raises(KeyError):
        DesignSpace(AcceleratorTemplate(), {"warp_cores": (1, 2)})


def test_wide_space_is_lazy():
    space = get_space("wide")
    assert len(space) > 10_000
    # taking a few points must not expand the space
    first = [space.point(i) for i in (0, len(space) // 2, len(space) - 1)]
    assert len({p.name for p in first}) == 3


def test_sampling_grid_and_halton_deterministic():
    space = get_space("wide")
    g1 = space.sample(16, method="grid")
    g2 = space.sample(16, method="grid")
    assert [p.index for p in g1] == [p.index for p in g2]
    assert len(g1) == 16
    h1 = space.sample(16, method="halton")
    h2 = space.sample(16, method="halton")
    assert [p.index for p in h1] == [p.index for p in h2]
    assert len({p.index for p in h1}) == 16
    assert [p.index for p in h1] != [p.index for p in g1]
    with pytest.raises(ValueError):
        space.sample(4, method="sobol")


# -- frontier ------------------------------------------------------------------


def _score(name, tput, sram, area, feasible=True):
    return DesignScore(name=name, params={}, throughput=tput,
                       throughput_unit="GOPS", sram_bytes=sram,
                       area_proxy=area, feasible=feasible)


def test_pareto_dominance_on_hand_built_points():
    a = _score("gen/a", tput=10.0, sram=100, area=5.0)
    b = _score("gen/b", tput=8.0, sram=100, area=6.0)   # dominated by a
    c = _score("gen/c", tput=12.0, sram=200, area=7.0)  # trade-off: stays
    f = pareto([c, a, b])
    assert [s.name for s in f.frontier] == ["gen/c", "gen/a"]
    assert len(f.dominated) == 1
    rec = f.dominated[0]
    assert rec.design == "gen/b" and rec.dominated_by == "gen/a"
    assert rec.deltas["throughput"] == pytest.approx(2.0)
    assert rec.deltas["area_proxy"] == pytest.approx(-1.0)
    # order-independence: any input order, same frontier
    g = pareto([b, c, a])
    assert [s.name for s in g.frontier] == [s.name for s in f.frontier]
    assert [r.as_dict() for r in g.dominated] == \
        [r.as_dict() for r in f.dominated]


def test_pareto_keeps_infeasible_out_but_recorded():
    a = _score("gen/a", 10.0, 100, 5.0)
    dead = _score("gen/dead", 0.0, 50, 1.0, feasible=False)
    f = pareto([a, dead])
    assert [s.name for s in f.frontier] == ["gen/a"]
    assert [s.name for s in f.infeasible] == ["gen/dead"]
    d = f.as_dict()
    assert d["objectives"][0] == {"name": "throughput", "direction": "max"}


def test_score_designs_and_frontier_deterministic():
    space = get_space("smoke")
    s1 = score_designs(space)
    s2 = score_designs(space)
    assert [s.as_dict() for s in s1] == [s.as_dict() for s in s2]
    assert all(s.throughput > 0 and s.feasible for s in s1)
    f = pareto(s1)
    assert 1 <= len(f.frontier) <= len(s1)
    assert len(f.frontier) + len(f.dominated) == len(s1)
    # nothing leaked into the registry
    assert machines.list_machines("gen/*") == []


def test_score_designs_with_model_config():
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b", smoke=True)
    pts = get_space("smoke").sample(2)
    scores = score_designs(pts, cfg=cfg, batch=4)
    assert all(s.throughput_unit == "tokens/s" for s in scores)
    assert all(s.feasible and s.throughput > 0 for s in scores)
    assert all(s.detail["arch"] == cfg.name for s in scores)


def test_rerank_by_slo_orders_attaining_first():
    from repro.configs import get_config
    from repro.design import rerank_by_slo

    cfg = get_config("qwen2-1.5b", smoke=True)
    pts = list(get_space("smoke").points())
    scores = score_designs(pts, cfg=cfg, batch=4)
    f = pareto(scores, workload="decode")
    ranked = rerank_by_slo(f, pts, cfg, slo={"p99_latency_s": 10.0},
                           batch=4, requests=60)
    assert ranked and all(r["attained"] for r in ranked)
    goodputs = [r["goodput_tps"] for r in ranked]
    assert goodputs == sorted(goodputs, reverse=True)


# -- grounding -----------------------------------------------------------------


def test_ground_end_to_end(tmp_path):
    """Acceptance: expand -> sample -> Calibrator.fit -> validated MAPE
    finite, with the grounded spec recovering the synthetic truth."""
    from repro.design import ground, sample_design, synthetic_truth
    from repro.measure import SampleStore

    pt = get_space("smoke").point(3)
    spec = pt.spec()
    truth = synthetic_truth(spec, bw=0.7, arith=0.85)
    store = SampleStore(str(tmp_path / "design.jsonl"))
    camp = sample_design(pt, store, grid="smoke", truth=truth)
    assert camp.samples
    result = ground(pt, store, date="2026-08-08")
    assert result.spec.provenance["grounded"] is True
    assert result.spec.provenance["template"] == spec.provenance["template"]
    assert np.isfinite(result.mape) and result.mape < 1.0
    # the fit found the truth, not the template derivation
    assert result.spec.rate("M", "L1") == \
        pytest.approx(truth.rate("M", "L1"), rel=1e-6)
    assert result.spec.arith_rate["int8"] == \
        pytest.approx(truth.arith_rate["int8"], rel=1e-6)


def test_ground_with_overhead_column(tmp_path):
    from repro.design import ground, sample_design, synthetic_truth
    from repro.measure import SampleStore

    pt = get_space("smoke").point(0)
    truth = synthetic_truth(pt.spec())
    store = SampleStore(str(tmp_path / "d.jsonl"))
    sample_design(pt, store, grid="smoke", truth=truth)
    result = ground(pt, store, date=None, overhead_per_block=True)
    fit_prov = result.spec.provenance["fit"]
    assert "overhead:block" in fit_prov["columns"]
    assert np.isfinite(result.mape)


# -- calibrator overhead column (unit level) -----------------------------------


def test_overhead_column_matches_scalar_oracle_and_recovers():
    from repro.core.variants import MicroKernel
    from repro.machines.calibrate import Calibrator, OVERHEAD_COL

    gap8 = machines.get("gap8-fc")
    cal = Calibrator(gap8, model="blis", policy="padded")
    probs = [(256, 784, 2304), (64, 3136, 576), (128, 784, 1152),
             (32, 12544, 27), (96, 196, 1024), (48, 3136, 64),
             (200, 200, 200), (512, 64, 512)]
    mks = [MicroKernel(*mk) for mk in
           ((4, 24), (8, 12), (12, 8), (16, 4))] * 2
    A, cols = cal.design_matrix(probs, mks, overhead_per_block=True)
    As, cols_s = cal.design_matrix_scalar(probs, mks,
                                          overhead_per_block=True)
    assert cols == cols_s and cols[-1] == OVERHEAD_COL
    assert np.array_equal(A, As)
    # synthesize times with a known 5us/dispatch overhead: the fit
    # recovers both the overhead and the unpolluted rates
    x_true = np.array([1.0 / cal._template_rate(c) for c in cols[:-1]]
                      + [5e-6])
    t = A @ x_true
    spec, rep = cal.fit(probs, t, micro_kernels=mks, date=None,
                        overhead_per_block=True)
    assert rep.overhead_per_block_s == pytest.approx(5e-6, rel=1e-6)
    assert spec.provenance["fit"]["overhead_per_block_s"] == \
        pytest.approx(5e-6, rel=1e-6)
    assert spec.rate("M", "L1") == pytest.approx(gap8.rate("M", "L1"),
                                                 rel=1e-6)
    # without the column, the same data fits measurably worse
    _, rep0 = cal.fit(probs, t, micro_kernels=mks, date=None,
                      on_nonpositive="free")
    assert rep0.insample_mape_pct > rep.insample_mape_pct


def test_overhead_column_rejected_off_blis():
    from repro.machines.calibrate import Calibrator

    cal = Calibrator(machines.get("tpu-v5e"), model="pallas")
    with pytest.raises(ValueError, match="overhead_per_block"):
        cal.design_matrix([(128, 128, 128)], overhead_per_block=True)


def test_microkernel_invocations_batch_matches_scalar():
    from repro.core.variants import (
        Blocking,
        MicroKernel,
        Problem,
        Variant,
        derive_blocking,
        microkernel_invocations,
        microkernel_invocations_batch,
    )

    gap8 = machines.get("gap8-fc")
    probs = [Problem(96, 196, 1024), Problem(32, 12544, 27),
             Problem(200, 200, 200)]
    mk = MicroKernel(8, 12)
    for variant in Variant:
        for policy in ("analytic", "padded"):
            blks = [derive_blocking(variant, mk, gap8, p) for p in probs]
            scalar = [microkernel_invocations(variant, mk, b, p, policy)
                      for p, b in zip(probs, blks)]
            rows = np.full(len(probs), mk.rows)
            cols = np.full(len(probs), mk.cols)
            m = np.array([p.m for p in probs])
            n = np.array([p.n for p in probs])
            k = np.array([p.k for p in probs])
            blk = (np.array([b.m_c for b in blks]),
                   np.array([b.n_c for b in blks]),
                   np.array([b.k_c for b in blks]))
            batch = microkernel_invocations_batch(
                variant, rows, cols, blk, m, n, k, policy)
            assert np.array_equal(np.asarray(scalar, np.float64), batch)


# -- CLI -----------------------------------------------------------------------


def test_cli_frontier_smoke(capsys):
    from repro.design.__main__ import main

    assert main(["frontier", "--space", "smoke"]) == 0
    out = capsys.readouterr().out
    assert "on frontier" in out
    assert machines.list_machines("gen/*") == []


def test_cli_sweep_cleans_namespace(capsys):
    from repro.design.__main__ import main

    assert main(["sweep", "--space", "smoke", "--limit", "2",
                 "--grid", "smoke", "--dtype", "int8"]) == 0
    assert machines.list_machines("gen/*") == []
