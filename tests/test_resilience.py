"""Overload resilience: deadlines, shedding, backpressure, degradation,
fault injection, and perturbation-robust autoconfiguration/calibration.

The acceptance properties of the resilience PR:

* an overload that previously died in ``DrainTruncatedError`` completes
  via shedding/degradation, with the causes in ``perf_report()``;
* the simulator sheds by the *same* rule as the real engine — replaying
  a trace with shed requests reproduces the shed set rid for rid;
* fault scenarios are seeded-reproducible: same scenario, same report;
* ``autoconfigure(robust=True)`` picks a different cell than the
  fair-weather mode, and the fair-weather pick fails under the faults
  with a machine-readable ``fault_``-prefixed rejection;
* ``Calibrator.fit(robust=...)`` recovers rates to <2% on a campaign
  with 10% planted outliers where plain least squares misses by far
  more, and the drift gate refuses a store that disagrees wholesale
  with the registered spec.
"""
import itertools
import json
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.resilience import (
    SHED_DEADLINE_EXPIRED,
    SHED_DEADLINE_UNMEETABLE,
    SHED_QUEUE_FULL,
    DegradationRung,
    QueueFullError,
    coerce_ladder,
    default_ladder,
    retry_with_backoff,
)
from repro.simulate import (
    SCENARIOS,
    SLO,
    ArrivalSurge,
    FaultScenario,
    PoissonTraffic,
    ServiceModel,
    ThrottleWindow,
    TraceTraffic,
    replay,
    simulate_serving,
    throttle_scenario,
)
from repro.simulate.autoconf import FAULT_REJECT_PREFIX, REJECT_SLO_SHED
from repro.simulate.faults import SURGE_RID_BASE
from repro.simulate.traffic import SimRequest
from repro.serving.buckets import PREFILL_BUCKETS

QWEN = "qwen2-1.5b"


def _service(decode=0.01):
    return ServiceModel(decode_step_s=decode,
                        prefill_s={b: 0.05 for b in PREFILL_BUCKETS})


@pytest.fixture(scope="module")
def smoke_lm():
    import jax
    from repro.models.common import HOST_MESH, split_params
    from repro.models.model import LM

    cfg = get_config(QWEN, smoke=True)
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(0)))
    return lm, values


# ---------------------------------------------------------------------------
# Fault scenarios
# ---------------------------------------------------------------------------


def test_throttle_window_math_and_period_folding():
    s = throttle_scenario(factor=1.5, duty=0.2, period_s=10.0)
    assert s.name == "throttle20"
    assert s.service_scale(0.0) == 1.5
    assert s.service_scale(1.99) == 1.5
    assert s.service_scale(2.0) == 1.0       # window is [0, 2)
    assert s.service_scale(9.9) == 1.0
    assert s.service_scale(10.3) == 1.5      # folded into the next period
    assert s.service_scale(25.0) == 1.0
    # overlapping windows compound
    both = FaultScenario(name="x", throttles=(
        ThrottleWindow(start_s=0, duration_s=2, factor=2.0),
        ThrottleWindow(start_s=1, duration_s=2, factor=3.0)))
    assert both.service_scale(1.5) == 6.0
    with pytest.raises(ValueError, match="duty"):
        throttle_scenario(duty=1.5)


def test_fault_scenario_coerce_and_round_trip():
    s = FaultScenario.coerce("throttle20")
    assert s is SCENARIOS["throttle20"]
    rt = FaultScenario.from_dict(s.as_dict())
    assert rt == s
    storm = SCENARIOS["storm"]
    assert FaultScenario.from_dict(json.loads(
        json.dumps(storm.as_dict()))) == storm
    with pytest.raises(ValueError, match="unknown fault scenario"):
        FaultScenario.coerce("nope")
    with pytest.raises(TypeError):
        FaultScenario.coerce(42)
    with pytest.raises(ValueError, match="schema"):
        FaultScenario.from_dict({"schema": "bogus", "name": "x"})


def test_failure_stream_is_seeded_and_surges_carry_high_rids():
    s = FaultScenario(name="f", slot_mtbf_s=2.0, seed=7)
    a = list(itertools.islice(s.failures(), 8))
    b = list(itertools.islice(s.failures(), 8))
    assert a == b                               # fresh identical stream
    assert a != list(itertools.islice(
        FaultScenario(name="f", slot_mtbf_s=2.0, seed=8).failures(), 8))
    assert list(FaultScenario(name="calm").failures()) == []
    crowd = FaultScenario(name="c", surges=(
        ArrivalSurge(at_s=1.0, requests=3, prompt_len=8, decode_len=4),))
    reqs = crowd.surge_requests()
    assert [r.rid for r in reqs] == [SURGE_RID_BASE, SURGE_RID_BASE + 1,
                                     SURGE_RID_BASE + 2]
    assert all(r.arrival_s == 1.0 and r.decode_len == 4 for r in reqs)


def test_fault_injection_is_reproducible_and_seed_sensitive():
    traffic = PoissonTraffic(rate=20, prompt_len=16, decode_len=8, seed=1)
    flaky = FaultScenario(name="flaky", slot_mtbf_s=0.05, seed=0)

    def run(scn):
        return simulate_serving(_service(), traffic, max_batch=2,
                                requests=30, faults=scn)

    a, b = run(flaky), run(flaky)
    assert a.to_json() == b.to_json()
    c = run(FaultScenario(name="flaky", slot_mtbf_s=0.05, seed=1))
    assert c.to_json() != a.to_json()
    assert a.faults["slot_failures"] > 0


def test_slot_failures_requeue_and_still_finish():
    traffic = TraceTraffic([
        SimRequest(rid=i, arrival_s=0.01 * i, prompt_len=16, decode_len=8)
        for i in range(6)])
    rep = simulate_serving(
        _service(), traffic, max_batch=2, requests=6,
        faults=FaultScenario(name="flaky", slot_mtbf_s=0.04, seed=3))
    assert rep.faults["slot_failures"] > 0
    assert rep.requests["finished"] == 6
    assert rep.requests["unfinished"] == 0
    # a victim re-prefills from scratch, so the run takes more steps than
    # the unperturbed one
    calm = simulate_serving(_service(), traffic, max_batch=2, requests=6)
    assert rep.steps > calm.steps


# ---------------------------------------------------------------------------
# Simulator shedding
# ---------------------------------------------------------------------------


def test_sim_sheds_unmeetable_at_admission_not_the_whole_queue():
    # decode costs 16 * 0.01 = 0.16s; rid1's 0.05s budget can never fit,
    # rid2's 10s budget easily does — shedding must skip rid1 and still
    # admit rid2 in the same step (a shed never consumes the slot)
    traffic = TraceTraffic([
        SimRequest(rid=0, arrival_s=0.0, prompt_len=16, decode_len=16),
        SimRequest(rid=1, arrival_s=0.0, prompt_len=16, decode_len=16,
                   deadline_s=0.05),
        SimRequest(rid=2, arrival_s=0.0, prompt_len=16, decode_len=16,
                   deadline_s=10.0),
    ])
    rep = simulate_serving(_service(), traffic, max_batch=2, requests=3)
    assert rep.requests == {"submitted": 3, "finished": 2, "shed": 1,
                            "unfinished": 0}
    assert rep.shed["causes"] == {SHED_DEADLINE_UNMEETABLE: 1}
    assert sorted(rep.finish_order) == [0, 2]
    assert rep.deadline["met"] == 1        # rid2; rid1 never finished


def test_sim_sheds_expired_after_queueing_and_counts_violations():
    # single slot: rid0 occupies it for ~0.21s; rid1's 0.1s budget has
    # expired by the time a slot frees
    traffic = TraceTraffic([
        SimRequest(rid=0, arrival_s=0.0, prompt_len=16, decode_len=16),
        SimRequest(rid=1, arrival_s=0.0, prompt_len=16, decode_len=1,
                   deadline_s=0.1),
        # admitted (0.21 + 0.16 <= 0.40) but finishes at ~0.42: a
        # deadline *violation*, distinct from a shed
        SimRequest(rid=2, arrival_s=0.0, prompt_len=16, decode_len=16,
                   deadline_s=0.40),
    ])
    rep = simulate_serving(_service(), traffic, max_batch=1, requests=3)
    assert rep.shed["causes"] == {SHED_DEADLINE_EXPIRED: 1}
    # both deadline-carrying requests missed: rid1 was shed, rid2 finished
    # late — but only rid2 shows up as a finished-but-late violation
    assert rep.deadline == {"requests": 2, "met": 0, "violated": 2}
    assert rep.requests["finished"] == 2
    assert 2 in rep.finish_order


def test_sim_bounded_queue_drops_with_queue_full_cause():
    traffic = TraceTraffic([
        SimRequest(rid=i, arrival_s=0.0, prompt_len=16, decode_len=4)
        for i in range(3)])
    rep = simulate_serving(_service(), traffic, max_batch=1, requests=3,
                           queue_limit=1)
    assert rep.shed["causes"] == {SHED_QUEUE_FULL: 2}
    assert rep.requests["finished"] == 1
    assert rep.config["queue_limit"] == 1


def test_sim_overload_on_gap9_completes_by_shedding():
    """The overload acceptance: >=2x the sustainable arrival rate on the
    gap9-fc analytic service — without resilience the queue grows without
    bound; with a deadline the run sheds the excess and finishes the
    rest, leaving nothing unfinished."""
    cfg = get_config(QWEN, smoke=True)
    service = ServiceModel.from_plans(cfg, batch=4, machine="gap9-fc",
                                      dtype="bf16", backend="analytic-tpu",
                                      max_len=512)
    decode_len = 16
    sustainable_rps = 4 / (service.decode_step_s * decode_len)
    traffic = PoissonTraffic(rate=2.5 * sustainable_rps, prompt_len=16,
                             decode_len=decode_len, seed=0)
    rep = simulate_serving(service, traffic, max_batch=4, requests=120,
                           deadline_s=0.5)
    assert rep.requests["unfinished"] == 0
    assert rep.shed_count > 0
    assert rep.requests["finished"] + rep.shed_count == 120
    assert rep.shed_fraction > 0.1          # real overload, really shed
    # the survivors' tail is bounded by the budget the shedder enforced
    assert rep.latency["p99"] <= 0.5 + service.prefill_seconds(16) \
        + decode_len * service.decode_step_s


def test_slo_max_shed_fraction_rejects_shed_everything():
    # an impossible per-request budget sheds the entire stream; without
    # max_shed_fraction that run would "attain" any latency bound
    traffic = TraceTraffic([
        SimRequest(rid=i, arrival_s=0.0, prompt_len=16, decode_len=16)
        for i in range(5)])
    rep = simulate_serving(_service(), traffic, max_batch=2, requests=5,
                           deadline_s=1e-6)
    assert rep.requests["finished"] == 0
    assert rep.shed_fraction == 1.0
    violations = SLO(p99_latency_s=10.0,
                     max_shed_fraction=0.2).check(rep)
    assert [v["reason"] for v in violations] == [REJECT_SLO_SHED]
    assert SLO(p99_latency_s=10.0).check(rep) == []


# ---------------------------------------------------------------------------
# Backpressure
# ---------------------------------------------------------------------------


def test_retry_with_backoff_schedule_on_fake_clock():
    delays, attempts = [], []

    def flaky():
        attempts.append(1)
        if len(attempts) < 4:
            raise QueueFullError(limit=2, depth=2)
        return "ok"

    out = retry_with_backoff(flaky, retries=5, base_delay_s=0.05,
                             multiplier=2.0, max_delay_s=0.15,
                             sleep=delays.append)
    assert out == "ok"
    assert len(attempts) == 4
    assert delays == [0.05, 0.1, 0.15]      # exponential, capped


def test_retry_with_backoff_exhausts_and_respects_predicate():
    delays = []

    def always_full():
        raise QueueFullError(limit=1, depth=1)

    with pytest.raises(QueueFullError):
        retry_with_backoff(always_full, retries=2, sleep=delays.append)
    assert len(delays) == 2                  # retries sleeps, then raise

    def boom():
        raise ValueError("not a queue problem")

    sleeps = []
    with pytest.raises(ValueError):
        retry_with_backoff(boom, retries=5, sleep=sleeps.append)
    assert sleeps == []                      # non-retryable: no backoff


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------


def test_default_ladder_shape_and_coercion():
    rungs = default_ladder(8)
    assert [r.decode_slots for r in rungs] == [4, 4]
    assert rungs[1].kv_dtype == "int8"
    assert default_ladder(1) == ()
    assert coerce_ladder(None, 8) == rungs
    assert coerce_ladder((), 8) == ()
    assert coerce_ladder([{"name": "r", "decode_slots": 2}], 4) == \
        (DegradationRung(name="r", decode_slots=2),)
    with pytest.raises(ValueError, match="wants 9 slots"):
        coerce_ladder([DegradationRung(name="big", decode_slots=9)], 8)
    with pytest.raises(ValueError, match=">= 1 decode slot"):
        DegradationRung(name="zero", decode_slots=0)


# ---------------------------------------------------------------------------
# Real engine
# ---------------------------------------------------------------------------


def test_engine_resilience_off_is_bit_identical(smoke_lm):
    from repro.serving.engine import Request, ServingEngine

    lm, values = smoke_lm

    def run(**kw):
        eng = ServingEngine(lm, values, max_batch=4, max_len=128, **kw)
        for i in range(4):
            eng.submit(Request(rid=i, prompt=[3 + i, 5, 7],
                               max_new_tokens=6))
        done = eng.run_until_drained()
        return eng, [r.generated for r in sorted(done, key=lambda r: r.rid)]

    plain_eng, plain = run()
    # armed but never stressed: generous budgets, no overload
    res_eng, res = run(deadline_s=1e9, queue_limit=100)
    assert res == plain
    assert res_eng.shed_requests == [] and res_eng.degradations == []
    assert plain_eng.perf_report().get("resilience") is None
    rr = res_eng.perf_report()["resilience"]
    assert rr["shed"]["count"] == 0 and rr["degraded"]["rung"] is None


def test_engine_overload_completes_where_plain_truncates(smoke_lm):
    """The headline acceptance: same overload, plain engine dies in
    DrainTruncatedError, the deadline-armed engine sheds the hopeless
    work, finishes the rest, and reports the causes."""
    from repro.serving.engine import (DrainTruncatedError, Request,
                                     ServingEngine)

    lm, values = smoke_lm

    def overload(eng, deadlines):
        for i, dl in enumerate(deadlines):
            eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=50,
                               deadline_s=dl))
        return eng.run_until_drained(max_steps=60)

    plain = ServingEngine(lm, values, max_batch=2, max_len=128)
    with pytest.raises(DrainTruncatedError, match="truncated after 60"):
        overload(plain, [None, None, None])

    armed = ServingEngine(lm, values, max_batch=2, max_len=128,
                          deadline_s=1e-6)
    done = overload(armed, [3600.0, None, None])   # rid0 has a real budget
    assert [r.rid for r in done] == [0]
    assert len(done[0].generated) == 50
    assert sorted(r.rid for r in armed.shed_requests) == [1, 2]
    rr = armed.perf_report()["resilience"]
    assert rr["shed"]["count"] == 2
    assert rr["shed"]["causes"] == {SHED_DEADLINE_EXPIRED: 2}
    assert rr["expired"] == 2
    kinds = [e["type"] for e in armed.trace_events]
    assert kinds.count("shed") == 2 and "truncated" not in kinds


def test_engine_drain_on_truncate_report(smoke_lm):
    from repro.serving.engine import Request, ServingEngine

    lm, values = smoke_lm
    eng = ServingEngine(lm, values, max_batch=2, max_len=128)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[1, 2, 3], max_new_tokens=50))
    done = eng.drain(max_steps=5, on_truncate="report")
    assert done == []                        # nothing finished in 5 steps
    assert eng.truncated == {"finished": 0, "queued": 1, "active": 2,
                             "max_steps": 5}
    rr = eng.perf_report()["resilience"]
    assert rr["truncated"]["queued"] == 1
    assert any(e["type"] == "truncated" for e in eng.trace_events)
    with pytest.raises(ValueError, match="on_truncate"):
        eng.drain(on_truncate="ignore")


def test_engine_bounded_queue_backpressure(smoke_lm):
    from repro.serving.engine import Request, ServingEngine

    lm, values = smoke_lm
    eng = ServingEngine(lm, values, max_batch=1, max_len=128, queue_limit=1,
                        ladder=())
    eng.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=3))
    with pytest.raises(QueueFullError) as ei:
        eng.submit(Request(rid=1, prompt=[3, 4], max_new_tokens=3))
    assert ei.value.limit == 1 and ei.value.depth == 1
    assert eng.rejected_submits == 1
    # the rejected submit leaves a reject event but NO submit event, so a
    # replayed trace never sees the request the engine never accepted
    assert [e["rid"] for e in eng.trace_events
            if e["type"] == "reject"] == [1]
    assert [e["rid"] for e in eng.trace_events
            if e["type"] == "submit"] == [0]
    # retrying with engine-step backpressure eventually lands it
    req1 = Request(rid=1, prompt=[3, 4], max_new_tokens=3)
    retry_with_backoff(lambda: eng.submit(req1),
                       sleep=lambda _dt: eng.step())
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1]
    # 2 rejects total: the raw submit above plus the retry's first attempt
    assert eng.perf_report()["resilience"]["rejected_submits"] == 2


def test_engine_degrades_under_sustained_overload_and_restores(smoke_lm):
    from repro.serving.engine import Request, ServingEngine

    lm, values = smoke_lm
    eng = ServingEngine(lm, values, max_batch=4, max_len=128,
                        queue_limit=64, overload_patience=2)
    assert [r.name for r in eng.ladder] == ["half-batch2",
                                            "half-batch2-int8kv"]
    for i in range(16):
        eng.submit(Request(rid=i, prompt=[1 + i, 2], max_new_tokens=12))
    done = eng.run_until_drained()
    assert len(done) == 16                   # degraded, but nothing lost
    kinds = [e["type"] for e in eng.degradations]
    assert "degrade" in kinds
    rr = eng.perf_report()["resilience"]
    assert len(rr["degraded"]["events"]) == len(eng.degradations)
    # the ladder caps admission while degraded: reconstruct active counts
    # from the step events — once degraded, admissions never push the
    # active set past the rung's slot cap
    degraded_at = next(e["t"] for e in eng.degradations
                       if e["type"] == "degrade")
    for e in eng.trace_events:
        if e["type"] == "step" and e["t"] > degraded_at and e["admitted"]:
            assert e["active"] <= 2


def test_engine_shed_trace_replays_to_matching_shed_set(smoke_lm):
    """Sim-vs-real shedding agreement: the simulator replays the real
    trace's arrival stream through its own shed rule and rejects exactly
    the rids the engine rejected."""
    from repro.serving.engine import Request, ServingEngine

    lm, values = smoke_lm
    eng = ServingEngine(lm, values, max_batch=1, max_len=128,
                        deadline_s=1e-6, ladder=())
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=4,
                       deadline_s=3600.0))
    eng.submit(Request(rid=1, prompt=[4, 5], max_new_tokens=4))
    eng.submit(Request(rid=2, prompt=[6, 7], max_new_tokens=4))
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [0]
    assert sorted(r.rid for r in eng.shed_requests) == [1, 2]
    trace = eng.trace_json()
    assert trace["predicted_step_s"] > 0
    rep = replay(trace)
    assert rep.shed_match
    assert set(rep.sim_shed) == {1, 2}
    assert set(rep.real_shed) == {1, 2}
    assert rep.order_match
    summary = rep.summary()
    assert summary["shed"]["match"] is True


def test_autoconfigure_robust_picks_fault_tolerant_cell(smoke_lm):
    """Robust-autoconfiguration acceptance on the gap9-fc grid: the
    fair-weather SLO pick and the robust pick differ, the fair-weather
    winner fails under the throttle with a fault_-prefixed rejection,
    and the robust winner meets the SLO *under* the faults."""
    from repro.serving.engine import ServingEngine

    lm, values = smoke_lm
    kwargs = dict(machine="gap9-fc", batches=(1, 2, 4, 8, 16), max_len=512)
    traffic = PoissonTraffic(rate=5, prompt_len=16, decode_len=16, seed=0)
    slo = SLO(p99_latency_s=0.45)
    faults = throttle_scenario(factor=1.3, duty=0.2, period_s=10.0)

    fair = ServingEngine.autoconfigure(lm, values, slo=slo, traffic=traffic,
                                       sim_requests=150, **kwargs)
    robust = ServingEngine.autoconfigure(lm, values, slo=slo,
                                         traffic=traffic, faults=faults,
                                         sim_requests=150, **kwargs)
    assert fair.max_batch != robust.max_batch
    ac = robust.autoconfig["slo"]
    assert ac["faults"] == faults.name
    assert robust.autoconfig["slo"]["sim"]["latency"]["p99"] <= 0.45
    # the fair-weather winner is among the fault-mode rejections, coded
    rejected = {r["batch"]: r["reason"] for r in ac["rejected"]}
    assert fair.max_batch in rejected
    assert rejected[fair.max_batch].startswith(FAULT_REJECT_PREFIX)
    # robust without an SLO is meaningless and says so
    with pytest.raises(ValueError, match="robust=True"):
        ServingEngine.autoconfigure(lm, values, robust=True, **kwargs)


# ---------------------------------------------------------------------------
# Robust calibration + drift gate
# ---------------------------------------------------------------------------


def _planted_outlier_campaign():
    """A gap9-fc campaign priced exactly by the template, with 10% of the
    rows corrupted x20 (a thermal brown-out during measurement)."""
    from repro.core.variants import MicroKernel
    from repro.machines import resolve
    from repro.machines.calibrate import Calibrator
    from repro.measure.campaign import DEFAULT_FIT_MKS, grid_problems

    spec = resolve("gap9-fc")
    probs, mks = [], []
    for p in grid_problems("mobilenet"):
        for mk in DEFAULT_FIT_MKS:
            probs.append(p)
            mks.append(MicroKernel(*mk))
    cal = Calibrator(spec, model="blis", policy="analytic")
    A, cols = cal.design_matrix(probs, mks)
    x_true = np.array([1.0 / cal._template_rate(c) for c in cols])
    t = A @ x_true
    rng = np.random.default_rng(0)
    outliers = sorted(rng.choice(len(t), size=len(t) // 10,
                                 replace=False).tolist())
    t[outliers] *= 20.0
    return cal, probs, mks, t, cols, outliers


def _max_rate_err(cal, spec, cols):
    errs = []
    for c in cols:
        if c.startswith("rate:"):
            o, _, d = c[len("rate:"):].partition("->")
            got = spec.transfer_rates[(o, d)]
        else:
            got = spec.arith_rate[c[len("arith:"):]]
        errs.append(abs(got / cal._template_rate(c) - 1.0))
    return max(errs)


@pytest.mark.parametrize("kind", ["huber", "trim"])
def test_robust_fit_recovers_rates_through_planted_outliers(kind):
    cal, probs, mks, t, cols, outliers = _planted_outlier_campaign()
    ols_spec, ols_rep = cal.fit(probs, t, micro_kernels=mks, date=None,
                                weighting="relative")
    rob_spec, rob_rep = cal.fit(probs, t, micro_kernels=mks, date=None,
                                weighting="relative", robust=kind)
    ols_err = _max_rate_err(cal, ols_spec, cols)
    rob_err = _max_rate_err(cal, rob_spec, cols)
    assert rob_err < 0.02                       # the acceptance bar
    assert ols_err > 0.05
    assert ols_err > 10 * max(rob_err, 1e-6)
    # the flagged rows cover the planted corruption
    assert set(outliers) <= set(rob_rep.outliers)
    if kind == "trim":
        assert sorted(rob_rep.outliers) == outliers
    # inlier residual is honest (near-exact) and provenance records it all
    assert rob_rep.residual_rms_s < ols_rep.residual_rms_s
    prov = rob_rep.as_provenance()
    assert prov["robust"] == kind
    assert prov["outlier_samples"] == rob_rep.outliers
    assert rob_spec.provenance["fit"]["robust"] == kind


def test_fit_robust_argument_validation():
    cal, probs, mks, t, _, _ = _planted_outlier_campaign()
    with pytest.raises(ValueError, match="robust"):
        cal.fit(probs, t, micro_kernels=mks, date=None, robust="median")
    with pytest.raises(ValueError, match="trim_fraction"):
        cal.fit(probs, t, micro_kernels=mks, date=None, robust="trim",
                trim_fraction=0.7)


def test_drift_gate_refuses_wholesale_disagreement(tmp_path):
    from repro import machines, measure

    truth = machines.get("gap8-fc")
    # aligned store: the gate passes and the fit proceeds
    ok = measure.SampleStore(str(tmp_path / "ok.jsonl"))
    measure.run_campaign("smoke", machine=truth, harness="simulated",
                         truth=truth, dtype="int8", store=ok)
    spec, _ = measure.fit_from_store(ok, truth, date=None, name="g-ok",
                                     max_drift=0.2)
    assert spec.name == "g-ok"

    # drifted store: the machine is 2x slower than the spec claims
    drifted = truth.scaled(arith=0.5, bw=0.5, name="gap8-drifted")
    bad = measure.SampleStore(str(tmp_path / "bad.jsonl"))
    measure.run_campaign("smoke", machine=truth, harness="simulated",
                         truth=drifted, dtype="int8", store=bad)
    with pytest.raises(measure.CalibrationDriftError,
                       match="disagree") as ei:
        measure.fit_from_store(bad, truth, date=None, max_drift=0.2)
    d = ei.value.as_dict()
    assert d["error"] == "calibration_drift"
    assert d["baseline"] == "gap8-fc"
    assert d["median_ratio"] == pytest.approx(2.0, rel=1e-6)
    assert d["drift"] == pytest.approx(1.0, rel=1e-6)
    assert d["max_drift"] == 0.2
    assert math.isfinite(d["drift"]) and d["samples"] == 24
    # the gate is opt-in: without max_drift the same store still fits
    spec2, _ = measure.fit_from_store(bad, truth, date=None, name="g-bad")
    assert spec2.name == "g-bad"
