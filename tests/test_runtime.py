"""Runtime tests: optimizer, schedule, compression, data determinism,
checkpointing (atomic/keep-N/preemption/elastic), training integration,
watchdog, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ParallelConfig, ShapeConfig, TrainConfig
from repro.data import DataIterator, make_batch
from repro.models.common import HOST_MESH, split_params
from repro.models.model import LM
from repro.optim import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
    quantize_int8,
)
from repro.optim.compression import compress_tree, decompress_tree, init_error_buffer
from repro.runtime.fault import StepWatchdog
from repro.runtime.train_lib import init_train_state, make_train_step
from repro.serving.engine import Request, ServingEngine


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(weight_decay=0.0, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, 0.1, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip_caps_update_norm():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params, cfg)
    g = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(g, opt, params, 1e-3, cfg)
    assert m["grad_norm"] > 1e6  # reported pre-clip


def test_lr_schedule_shape():
    s = jnp.arange(0, 1000)
    lr = jax.vmap(lambda t: lr_schedule(t, base_lr=1.0, warmup=100,
                                        total=1000))(s)
    assert float(lr[0]) == 0.0
    assert float(lr[99]) == pytest.approx(0.99, abs=0.02)
    assert float(jnp.max(lr)) <= 1.0 + 1e-6
    assert float(lr[-1]) == pytest.approx(0.1, abs=0.01)   # min_ratio floor
    assert bool(jnp.all(lr[100:] >= 0.1 - 1e-6))


def test_moment_dtype_configurable():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    opt = init_opt_state({"w": jnp.zeros((4, 4))}, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_quantize_int8_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(size=128) * rng.uniform(0.01, 100), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(x - q.astype(jnp.float32) * s)
    assert float(err.max()) <= float(s) * 0.5 + 1e-9


def test_error_feedback_converges():
    """Repeatedly compressing the same gradient with error feedback must
    transmit the full signal over time (mean reconstructed -> true grad)."""
    g = {"w": jnp.array([1e-4, 3e-2, -0.7, 0.9])}
    ebuf = init_error_buffer(g)
    acc = jnp.zeros(4)
    n = 50
    for _ in range(n):
        q, ebuf = compress_tree(g, ebuf)
        deq = decompress_tree(q, g)
        acc = acc + deq["w"]
    # converges to within a small fraction of the int8 quantisation step
    # (scale = max|g|/127); components far below the step need ~1/eps rounds
    step = float(jnp.max(jnp.abs(g["w"]))) / 127
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               rtol=5e-2, atol=step / 10)


# ---------------------------------------------------------------------------
# Data pipeline determinism
# ---------------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeConfig("t", "train", 32, 4)
    it1 = DataIterator(cfg, shape, seed=7)
    batches = [next(it1) for _ in range(5)]
    # resume from state at step 3
    it2 = DataIterator(cfg, shape, seed=0)
    it2.load_state_dict({"step": 3, "seed": 7})
    b3 = next(it2)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]),
                                  np.asarray(batches[3]["tokens"]))


def test_data_host_sharding_disjoint():
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeConfig("t", "train", 16, 8)
    b0 = make_batch(cfg, shape, step=0, seed=1, host_id=0, num_hosts=2)
    b1 = make_batch(cfg, shape, step=0, seed=1, host_id=1, num_hosts=2)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_data_has_learnable_structure():
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeConfig("t", "train", 256, 8)
    b = make_batch(cfg, shape, step=0, seed=0)
    toks = np.asarray(b["tokens"])
    copies = (toks[:, 1:] == toks[:, :-1]).mean()
    assert 0.3 < copies < 0.7        # the copy-process signal


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {"a": jnp.array(rng.normal(size=(4, 4)), jnp.float32),
            "b": {"c": jnp.array(rng.normal(size=3), jnp.float32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(0)
    mgr.save(10, t, extra={"data": {"step": 10, "seed": 0}})
    step, restored, extra = mgr.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t))
    assert step == 10 and extra["data"]["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_checkpoint_keep_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A .tmp directory without the commit marker is never listed."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    os.makedirs(tmp_path / "step_00000002")   # crash-simulated partial
    assert mgr.all_steps() == [1]


def test_elastic_restore_across_meshes(tmp_path):
    """A checkpoint written under one topology restores under another
    (shardings arg re-places arrays) — the elastic-scaling contract."""
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save(5, t)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"a": NamedSharding(mesh, P()), "b": {"c": NamedSharding(mesh, P())}}
    _, restored, _ = mgr.restore_latest(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t),
        shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))
    assert restored["a"].sharding == sh["a"]


def test_preemption_flag(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    assert not mgr.preempted
    mgr.simulate_preemption()
    assert mgr.preempted


# ---------------------------------------------------------------------------
# Training integration (loss decreases; resume == uninterrupted)
# ---------------------------------------------------------------------------


def test_train_loop_improves_loss():
    from repro.launch.train import train
    out = train("qwen2-1.5b", steps=30, batch=8, seq=64, lr=3e-3)
    assert np.mean(out["losses"][-5:]) < np.mean(out["losses"][:5]) * 0.7


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Train 6 steps; vs train 3, 'crash', resume 3 — identical params."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeConfig("t", "train", 32, 4)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=2, total_steps=6)
    pcfg = ParallelConfig()
    lm = LM(cfg, HOST_MESH)
    step_fn = jax.jit(make_train_step(lm, tcfg, pcfg))

    def run(n_steps, params, opt, start=0):
        data = DataIterator(cfg, shape, seed=3)
        data.load_state_dict({"step": start, "seed": 3})
        for _ in range(n_steps):
            params, opt, _ = step_fn(params, opt, next(data))
        return params, opt

    p0, _, o0, _ = init_train_state(lm, tcfg, jax.random.key(0))
    pa, oa = run(6, p0, o0)

    p1, _, o1, _ = init_train_state(lm, tcfg, jax.random.key(0))
    pb, ob = run(3, p1, o1)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"params": pb, "opt": ob}, extra={"data": {"step": 3, "seed": 3}})
    _, state, extra = mgr.restore_latest(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                     {"params": pb, "opt": ob}))
    pc, oc = run(3, state["params"], state["opt"], start=extra["data"]["step"])

    for va, vc in zip(jax.tree.leaves(pa), jax.tree.leaves(pc), strict=True):
        np.testing.assert_allclose(np.asarray(va), np.asarray(vc),
                                   rtol=1e-6, atol=1e-7)


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation must equal the full-batch gradient (mean CE
    over equal-sized microbatches is exact)."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeConfig("t", "train", 32, 8)
    tcfg = TrainConfig(lr=0.0, warmup_steps=1, total_steps=2, grad_clip=0.0)
    lm = LM(cfg, HOST_MESH)
    p, _, o, _ = init_train_state(lm, tcfg, jax.random.key(1))
    batch = make_batch(cfg, shape, 0, seed=5)
    f1 = jax.jit(make_train_step(lm, tcfg, ParallelConfig(microbatches=1)))
    f4 = jax.jit(make_train_step(lm, tcfg, ParallelConfig(microbatches=4)))
    _, o1, m1 = f1(p, o, batch)
    _, o4, m4 = f4(p, o, batch)
    # same loss and same first-moment buffers (loss is mean over tokens)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]),
                                                   rel=1e-3)
    l1 = jax.tree.leaves(o1["m"])
    l4 = jax.tree.leaves(o4["m"])
    # bf16 forward/backward: accumulation order differs between the two
    # paths; agreement is to bf16 resolution, not f32
    worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l4))
    assert worst < 8e-3


def test_watchdog_flags_stragglers():
    import time
    wd = StepWatchdog(threshold=3.0)
    for _ in range(5):
        wd.start(); time.sleep(0.01); wd.stop()
    wd.start(); time.sleep(0.2); slow = wd.stop()
    assert slow and wd.straggler_steps == 1


# ---------------------------------------------------------------------------
# Serving engine == sequential greedy decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-1.2b"])
def test_engine_matches_sequential_greedy(arch):
    # f32 compute: greedy equivalence needs argmax stability, and bf16
    # leaves near-ties one ulp apart that flip with the batch shape (the
    # engine decodes B=3, the reference B=1).
    import dataclasses
    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              compute_dtype="float32",
                              kv_cache_dtype="float32")
    lm = LM(cfg, HOST_MESH)
    values, _ = split_params(lm.init(jax.random.key(3)))

    def reference(prompt, n_new):
        caches, _ = split_params(lm.init_cache(1, 128))
        toks = list(prompt)
        for t in range(len(prompt) + n_new - 1):
            tok = jnp.array([[toks[t]]], jnp.int32)
            logits, caches = lm.decode_step(values, caches, tok, jnp.int32(t))
            if t >= len(prompt) - 1:
                logits = logits.astype(jnp.float32
                                       ).at[..., cfg.vocab_size:].set(-1e9)
                toks.append(int(jnp.argmax(logits, axis=-1)[0]))
        return toks[len(prompt):]

    eng = ServingEngine(lm, values, max_batch=3, max_len=128)
    prompts = [[5, 6, 7, 8], [1, 2, 3], [9, 4, 2, 7, 5, 3], [11, 12]]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=5))
    done = eng.run_until_drained()
    assert len(done) == len(prompts)
    for r in done:
        assert r.generated == reference(prompts[r.rid], 5), r.rid


def test_train_with_int8_ef_compression_converges():
    """End-to-end training with int8 error-feedback gradient compression in
    the loop still reduces loss at a comparable rate."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeConfig("t", "train", 32, 8)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=2, total_steps=20)
    lm = LM(cfg, HOST_MESH)
    from repro.runtime.train_lib import init_train_state, make_train_step

    def run(pcfg):
        p, _, o, _ = init_train_state(lm, tcfg, jax.random.key(0), pcfg)
        step = jax.jit(make_train_step(lm, tcfg, pcfg))
        losses = []
        from repro.data import DataIterator
        it = DataIterator(cfg, shape, seed=11)
        for _ in range(15):
            p, o, m = step(p, o, next(it))
            losses.append(float(m["loss"]))
        return losses

    plain = run(ParallelConfig())
    comp = run(ParallelConfig(grad_compression="int8_ef"))
    assert comp[-1] < comp[0] * 0.8          # still learns
    # compressed run tracks the plain run loosely
    assert abs(comp[-1] - plain[-1]) / plain[-1] < 0.5
